"""Decomposition hot-path benchmark: PR-2 fast paths vs their pre-refactor
baselines, with bit-identity asserted before any number is reported.

Four families, each timed old vs new on CPU wall-clock and checked
bit-for-bit (the refactors are pure *schedule* changes — chunked integer
limb adds, fused dispatch, fused epilogues — so any mismatch is a bug,
not noise):

* ``quire_gemm``  — K-chunked unrolled deposit scan (kc=8, unroll=4) vs
                    the PR-1 per-column schedule (kc=1, unroll=1)
* ``rgetrf``      — single-dispatch jitted driver vs Python-loop driver
* ``rpotrf``      — same comparison for Cholesky
* ``rgemm``       — fused in-kernel posit encode vs f32-out + host encode,
                    plus the xla_quire reference path

Writes ``BENCH_decomp.json`` (schema: {meta, results: [{name, config,
t_old_ms, t_new_ms, speedup, identical}]}) — the perf trajectory seed the
CI perf-smoke job uploads as an artifact.  ``--quick`` shrinks sizes/reps
for CI; the full run covers the acceptance shapes (quire_gemm K=256,
rgetrf n=512).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.kernels.posit_gemm import posit_gemm_f32
from repro.lapack import decomp
from repro.quire.gemm import quire_gemm


def _time(fn, reps=3, warmup=2):
    """Best-of-N wall clock (ms) — min is the standard microbenchmark
    estimator: robust to scheduler/contention spikes on shared CI boxes,
    and the quantity the speedup claims are stated over."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)) * 1e3             # ms


def _time_pair(fn_old, fn_new, reps=3, warmup=1):
    """Interleaved best-of-N for old and new (ms, ms): alternating the two
    programs rep by rep puts both under the same machine conditions, so
    load drift cancels out of the speedup ratio instead of landing on
    whichever side ran second."""
    for _ in range(warmup):
        jax.block_until_ready(fn_old())
        jax.block_until_ready(fn_new())
    t_old, t_new = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_old())
        t_old.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_new())
        t_new.append(time.perf_counter() - t0)
    return float(np.min(t_old)) * 1e3, float(np.min(t_new)) * 1e3


def _identical(a, b):
    return bool(all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(jax.tree_util.tree_leaves(a),
                                    jax.tree_util.tree_leaves(b))))


def _attach_metrics(row, fn):
    """One observed (un-timed) re-run of the NEW path after the timing
    loop: golden-zone occupancy / call counters ride along in the bench
    row as a compact ``metrics`` block (merge_bench surfaces them)."""
    with obs.scoped() as m:
        jax.block_until_ready(fn())
    row["metrics"] = m.bench_block()
    return row


def _posit_matrix(rng, shape, lo=-8, hi=8):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return P.from_float64(jnp.asarray(x))


def _row(name, config, t_old, t_new, identical, results):
    r = {"name": name, "config": config, "t_old_ms": round(t_old, 3),
         "t_new_ms": round(t_new, 3),
         "speedup": round(t_old / t_new, 3), "identical": identical}
    results.append(r)
    flag = "" if identical else "  << MISMATCH"
    print(f"{name:<14} {config:<28} old {t_old:8.1f}ms  new {t_new:8.1f}ms "
          f"  {r['speedup']:5.2f}x{flag}", flush=True)
    assert identical, f"{name} {config}: new path is not bit-identical"
    return r


def bench_quire_gemm(results, quick, reps):
    rng = np.random.default_rng(0)
    shapes = [(32, 128, 32)] if quick else [(64, 256, 64), (48, 256, 48),
                                            (32, 512, 32)]
    for (m, k, n) in shapes:
        ap = _posit_matrix(rng, (m, k))
        bp = _posit_matrix(rng, (k, n))
        old = quire_gemm(ap, bp, kc=1, unroll=1)
        new = quire_gemm(ap, bp)                # kc=8, unroll=4 default
        t_old, t_new = _time_pair(lambda: quire_gemm(ap, bp, kc=1, unroll=1),
                                  lambda: quire_gemm(ap, bp), reps)
        _row("quire_gemm", f"{m}x{k}x{n} kc8u4 vs per-col", t_old, t_new,
             _identical(old, new), results)


def bench_factorizations(results, quick, reps):
    rng = np.random.default_rng(1)
    n = 128 if quick else 512
    nb = 32 if quick else 64
    a64 = rng.standard_normal((n, n))
    ap = P.from_float64(jnp.asarray(a64))
    sp = P.from_float64(jnp.asarray(a64.T @ a64))

    old = decomp.rgetrf_loop(ap, nb=nb)
    new = decomp.rgetrf(ap, nb=nb)
    t_old, t_new = _time_pair(lambda: decomp.rgetrf_loop(ap, nb=nb),
                              lambda: decomp.rgetrf(ap, nb=nb),
                              max(2, reps // 2))
    _attach_metrics(_row("rgetrf", f"n={n} nb={nb} jit vs loop", t_old,
                         t_new, _identical(old, new), results),
                    lambda: decomp.rgetrf(ap, nb=nb))

    old = decomp.rpotrf_loop(sp, nb=nb)
    new = decomp.rpotrf(sp, nb=nb)
    t_old, t_new = _time_pair(lambda: decomp.rpotrf_loop(sp, nb=nb),
                              lambda: decomp.rpotrf(sp, nb=nb),
                              max(2, reps // 2))
    _attach_metrics(_row("rpotrf", f"n={n} nb={nb} jit vs loop", t_old,
                         t_new, _identical(old, new), results),
                    lambda: decomp.rpotrf(sp, nb=nb))


def bench_rgemm(results, quick, reps):
    rng = np.random.default_rng(2)
    size = 128 if quick else 256
    ap = _posit_matrix(rng, (size, size), -4, 4)
    bp = _posit_matrix(rng, (size, size), -4, 4)

    # fused in-kernel encode vs the pre-refactor f32-out + host-f64 epilogue
    def old_pallas():
        ab = posit_gemm_f32(ap, bp).astype(jnp.float64)
        return P.from_float64(ab)

    new = rgemm(ap, bp, backend="pallas_split3")
    old = old_pallas()
    t_old, t_new = _time_pair(
        old_pallas, lambda: rgemm(ap, bp, backend="pallas_split3"), reps)
    _row("rgemm", f"{size}^3 pallas fused-encode", t_old, t_new,
         _identical(old, new), results)

    # xla_quire reference path (unchanged semantics; timed for trajectory)
    t_ref = _time(lambda: rgemm(ap, bp, backend="xla_quire"), reps)
    ref_row = {"name": "rgemm", "config": f"{size}^3 xla_quire",
               "t_old_ms": round(t_ref, 3), "t_new_ms": round(t_ref, 3),
               "speedup": 1.0, "identical": True}
    _attach_metrics(ref_row, lambda: rgemm(ap, bp, backend="xla_quire"))
    results.append(ref_row)
    print(f"{'rgemm':<14} {f'{size}^3 xla_quire':<28} ref {t_ref:8.1f}ms",
          flush=True)


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small sizes / fewer reps (CI perf-smoke)")
    parser.add_argument("--out", default="BENCH_decomp.json")
    args = parser.parse_args(argv)
    reps = 3 if args.quick else 10

    results = []
    bench_quire_gemm(results, args.quick, reps)
    bench_factorizations(results, args.quick, reps)
    bench_rgemm(results, args.quick, reps)

    payload = {
        "meta": {
            "bench": "bench_decomp", "quick": args.quick,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(results)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
