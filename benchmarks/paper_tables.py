"""One benchmark per paper table/figure (DESIGN.md §5 maps each).

All timings are CPU wall-clock of jit-compiled code (median of reps after
warmup); hardware-gated artifacts (FPGA synthesis, AC power) are modeled
and labeled as such.  Each function returns a list of
(name, us_per_call, derived) rows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.kernels.posit_gemm import posit_gemm_f32
from repro.lapack import decomp
from repro.lapack.error_eval import (backward_error_study,
                                     least_squares_study, refinement_study)

# paper Table 2 magnitude ranges
RANGES = {"I0": (1.0, 2.0), "I1": (1e-38, 1e-30), "I2": (1e30, 1e38),
          "I3": (1e-15, 1e-14), "I4": (1e14, 1e15)}


def _time(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6          # us


def _rand_posits(n, lo, hi, seed):
    rng = np.random.default_rng(seed)
    x = np.exp(rng.uniform(np.log(lo), np.log(hi), n))
    sign = rng.choice([-1.0, 1.0], n)
    return jnp.asarray(P.from_float64(x * sign))


def bench_table2_magnitude():
    """Paper Tables 2-3: op cost vs argument magnitude.

    The paper's GPU port is 2.1x slower outside the golden zone (regime
    loops + branch divergence).  The TPU adaptation is branch-free, so the
    cost is magnitude-independent BY CONSTRUCTION — the flat profile below
    is the adapted result (FPGA-like constancy; DESIGN.md §2)."""
    rows = []
    n = 200_000
    ops = {"add": P.jitted("add"), "mul": P.jitted("mul"),
           "div": P.jitted("div")}
    for rname, (lo, hi) in RANGES.items():
        a = _rand_posits(n, lo, hi, 1)
        b = _rand_posits(n, lo, hi, 2)
        for opname, op in ops.items():
            us = _time(op, a, b)
            rows.append((f"table2/{opname}/{rname}", us,
                         f"ns_per_elem={us * 1e3 / n:.3f}"))
        sq = P.jitted("sqrt")
        us = _time(sq, jnp.abs(a))
        rows.append((f"table2/sqrt/{rname}", us,
                     f"ns_per_elem={us * 1e3 / n:.3f}"))
    # Table 3 analog: static HLO op count (identical for every range —
    # the instruction-count blow-up of the paper's Table 3 is eliminated)
    lowered = jax.jit(lambda x, y: P.add(x, y)).lower(
        jax.ShapeDtypeStruct((8,), jnp.int32),
        jax.ShapeDtypeStruct((8,), jnp.int32))
    n_ops = str(lowered.compile().as_text()).count(" = ")
    rows.append(("table3/hlo_ops_per_add", 0.0,
                 f"static_op_count={n_ops};range_independent=True"))
    return rows


def bench_gemm_scaling():
    """Paper Figs. 2-4: GEMM throughput vs N and sigma.

    Reports the quire-semantics XLA path (production CPU path) and one
    Pallas interpret-mode point (kernel validation path; interpret mode is
    a correctness vehicle, not a speed vehicle)."""
    rows = []
    for n in (128, 256, 384):
        for sigma in (1e-2, 1.0, 1e6):
            rng = np.random.default_rng(0)
            a = P.from_float64(rng.standard_normal((n, n)) * sigma)
            b = P.from_float64(rng.standard_normal((n, n)) * sigma)
            f = jax.jit(lambda x, y: rgemm(x, y, backend="xla_quire"))
            us = _time(f, a, b)
            gflops = 2 * n ** 3 / (us * 1e-6) / 1e9
            rows.append((f"fig2-4/gemm_quire/N={n}/sigma={sigma:g}", us,
                         f"gflops={gflops:.3f}"))
    # one Pallas interpret-mode data point
    n = 128
    rng = np.random.default_rng(0)
    a = jnp.asarray(P.from_float64(rng.standard_normal((n, n))))
    b = jnp.asarray(P.from_float64(rng.standard_normal((n, n))))
    us = _time(lambda x, y: posit_gemm_f32(x, y), a, b, reps=2, warmup=1)
    rows.append((f"fig2-4/gemm_pallas_interpret/N={n}", us,
                 "mode=interpret(correctness-only)"))
    return rows


def bench_trailing_update():
    """Paper Fig. 6: non-square trailing-update GEMM (N x K) @ (K x N)
    relative throughput vs K."""
    rows = []
    n = 512
    base = None
    for k in (512, 256, 128, 32):
        rng = np.random.default_rng(0)
        a = P.from_float64(rng.standard_normal((n, k)))
        b = P.from_float64(rng.standard_normal((k, n)))
        f = jax.jit(lambda x, y: rgemm(x, y, backend="xla_quire"))
        us = _time(f, a, b)
        gflops = 2 * n * n * k / (us * 1e-6) / 1e9
        if base is None:
            base = gflops
        rows.append((f"fig6/trailing/K={k}", us,
                     f"gflops={gflops:.3f};rel_to_square={gflops/base:.3f}"))
    return rows


def bench_accuracy_decomp():
    """Paper Fig. 7 (the headline): digits of backward-error advantage of
    Posit(32,2) over binary32 for Cholesky/LU vs sigma.  The quire column
    repeats the golden-zone cell with gemm_backend='quire_exact' (true
    single-rounding trailing updates) — beyond-paper semantics."""
    rows = []
    for algo in ("cholesky", "lu"):
        for sigma in (1e-2, 1.0, 1e2, 1e4, 1e6):
            t0 = time.perf_counter()
            r = backward_error_study(96, sigma, algo, nb=32,
                                     gemm_backend="faithful")
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig7/{algo}/sigma={sigma:g}", us,
                         f"digits={r.digits:+.3f};e_posit={r.e_posit:.3e};"
                         f"e_b32={r.e_binary32:.3e}"))
        t0 = time.perf_counter()
        rq = backward_error_study(96, 1.0, algo, nb=32,
                                  gemm_backend="quire_exact")
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig7/{algo}/sigma=1/quire_exact", us,
                     f"digits={rq.digits:+.3f};e_posit={rq.e_posit:.3e};"
                     f"e_b32={rq.e_binary32:.3e}"))
    return rows


def bench_refinement():
    """Beyond-paper: quire-exact iterative refinement (lapack/refine.py)
    on the paper's §5.1 protocol at n=256, phi=0 ensemble (sigma=1).

    digits_gained = log10(e_plain / e_ir): decimal digits of backward
    error the refinement recovers over the plain Rgetrs/Rpotrs solve
    from the SAME posit32 factorization (acceptance bar: >= 2)."""
    rows = []
    for algo in ("lu", "cholesky"):
        t0 = time.perf_counter()
        r = refinement_study(256, 1.0, algo, nb=32, iters=3)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"refine/{algo}/N=256/phi=0", us,
                     f"e_plain={r.e_plain:.3e};e_ir={r.e_ir:.3e};"
                     f"digits_gained={r.digits_gained:+.2f}"))
    return rows


def bench_least_squares():
    """Beyond-paper: the over-determined scenario (lapack/qr.py) on the
    §5.1 protocol — Householder QR rgels vs binary32 sgels across the
    sigma grid, plus the refinement story: digits_from_opt ~ 0 means
    rgels_ir sits on the TRUE least-squares optimum of the posit-held
    problem (the data-quantization floor), and lost_mp ~ 0 means the
    p16e1-factorized rgels_mp lands on the same floor."""
    rows = []
    for sigma in (1e-2, 1.0, 1e2):
        t0 = time.perf_counter()
        r = least_squares_study(96, 64, sigma, nb=32)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"ls/qr/m=96/n=64/sigma={sigma:g}", us,
                     f"digits={r.digits:+.3f};"
                     f"from_opt={r.digits_from_opt:+.3f};"
                     f"lost_mp={r.digits_lost:+.3f}"))
    return rows


def bench_decomp_perf():
    """Paper Fig. 8 / Table 5: decomposition wall-clock, posit vs f32."""
    rows = []
    rng = np.random.default_rng(0)
    for n in (128, 256):
        x = rng.standard_normal((n, n))
        spd = x.T @ x
        ap = P.from_float64(jnp.asarray(spd))
        t0 = time.perf_counter()
        jax.block_until_ready(decomp.rpotrf(ap, nb=32))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig8/rpotrf/N={n}", us,
                     f"gflops={(n**3/3)/(us*1e-6)/1e9:.4f}"))
        gen = rng.standard_normal((n, n))
        gp = P.from_float64(jnp.asarray(gen))
        t0 = time.perf_counter()
        lu, piv = decomp.rgetrf(gp, nb=32)
        jax.block_until_ready(lu)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig8/rgetrf/N={n}", us,
                     f"gflops={(2*n**3/3)/(us*1e-6)/1e9:.4f}"))
        # binary32 baselines
        a32 = jnp.asarray(spd, jnp.float32)
        f = jax.jit(decomp.spotrf)
        us = _time(f, a32)
        rows.append((f"table5/spotrf/N={n}", us, "binary32-baseline"))
    return rows


def bench_dist_scaling():
    """Beyond-paper: distributed posit linear algebra (repro.dist) on a
    2x2 forced-host-device grid — pdgemm / p_rpotrf / p_rgetrf timed
    against their single-device counterparts AFTER bit-identity is
    asserted (the dist contract: sharding is a schedule change, words
    are invariant).  Host devices time-slice the same cores, so the
    ratio is schedule overhead, not scaling — see BENCH_dist.json."""
    import os
    try:
        import bench_dist as bd                  # script-style sys.path
    except ImportError:
        from benchmarks import bench_dist as bd  # package-style (run.py)
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    rows = []
    for r in bd.run_child(4, quick=True, bench_dir=bench_dir):
        rows.append((f"dist/{r['name']}/grid={r['grid'][0]}x{r['grid'][1]}",
                     r["t_dist_ms"] * 1e3,
                     f"identical={r['identical']};"
                     f"single_ms={r['t_single_ms']};"
                     f"speedup={r['speedup']}"))
    return rows


def bench_table1_kernel_model():
    """Paper Table 1 is FPGA synthesis (Fmax/logic cells) — hardware-gated.
    We report the structural analogue of the TPU kernel: VMEM bytes and
    FLOPs per (128,128,128) tile, and the decode/encode op budget."""
    bm = bn = bk = 128
    vmem_in = (bm * bk + bk * bn) * 4            # int32 posit words
    vmem_scratch = 2 * bm * bn * 4               # f32 acc + err
    flops_tile = 3 * 2 * bm * bn * bk            # 3 MXU passes (hi/lo split)
    rows = [
        ("table1/vmem_bytes_per_tile", 0.0,
         f"inputs={vmem_in};scratch={vmem_scratch};"
         f"total={vmem_in+vmem_scratch}"),
        ("table1/flops_per_tile", 0.0, f"flops={flops_tile};mxu_passes=3"),
        ("table1/note", 0.0,
         "FPGA_Fmax_and_logic_cells_are_hardware-gated;see_DESIGN.md"),
    ]
    return rows


def bench_power_model():
    """Paper Table 6 is AC wall power — hardware-gated on CPU.  We report
    a MODELED efficiency: TPU v5e chip TDP ~197W-class envelope is not
    public; we use the v5e spec point 197 TFLOP/s bf16 and a public ~215 W
    board envelope to give Gflops/W at the roofline-projected LU rate, and
    label it a model, not a measurement."""
    peak_tflops = 197.0
    board_watts = 215.0
    # LU at N=8000 reaches ~80% of GEMM peak on a well-tuned stack; the
    # posit path runs 3 MXU passes per logical GEMM (hi/lo split) -> 1/3
    # effective, times quire-mode accuracy (no per-MAC rounding penalty).
    eff = 0.8 / 3.0
    gflops_per_w = peak_tflops * 1e3 * eff / board_watts
    return [("table6/power_model", 0.0,
             f"modeled_gflops_per_watt={gflops_per_w:.1f};"
             f"assumptions=0.8_LU_eff,3x_split_passes,215W;"
             f"MEASUREMENT_HARDWARE_GATED=True")]


ALL_BENCHES = [
    bench_table2_magnitude,
    bench_gemm_scaling,
    bench_trailing_update,
    bench_accuracy_decomp,
    bench_refinement,
    bench_least_squares,
    bench_decomp_perf,
    bench_dist_scaling,
    bench_table1_kernel_model,
    bench_power_model,
]
