"""Roofline analysis over the dry-run records (spec: ROOFLINE ANALYSIS).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs / peak_FLOPs          [s, per device]
    memory term     = HLO_bytes / HBM_bw              [s, per device]
    collective term = collective_bytes / link_bw      [s, per device]

cost_analysis / the HLO parse operate on the SPMD-partitioned per-device
module, so all three terms are already per-chip; the spec's (chips x peak)
denominator cancels.  MODEL_FLOPS uses 6*N_active*D (train), 2*N_active*D
(prefill), 2*N_active*B (decode) per device.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Writes experiments/roofline.md and prints a CSV summary.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import jax
import numpy as np

PEAK_FLOPS = 197e12          # TPU v5e bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_LEVERS = {
    "compute": "raise MXU utilization: larger per-device batch/seq tiles, "
               "fewer redundant (remat) flops",
    "memory": "fuse elementwise chains / increase arithmetic intensity "
              "(bigger tiles, bf16 everywhere, avoid spills)",
    "collective": "reshard to cut cross-chip traffic (more FSDP locality, "
                  "posit16-compressed wire formats, overlap with compute)",
}


def _param_counts(arch: str):
    from repro.configs import get_config
    from repro.models import init_params
    import functools
    cfg = get_config(arch)
    abstract = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
    expert = 0
    if cfg.n_experts:
        def walk(t, inmoe=False):
            nonlocal expert
            if isinstance(t, dict):
                for k, v in t.items():
                    walk(v, inmoe or k in ("w_gate", "w_up", "w_down"))
            elif isinstance(t, (list, tuple)):
                for v in t:
                    walk(v, inmoe)
            elif hasattr(t, "shape") and inmoe:
                expert += int(np.prod(t.shape))
        walk(abstract["layers"])
        active = total - expert + expert * cfg.top_k / cfg.n_experts
    else:
        active = total
    return total, active, cfg


def model_flops(rec, active_params: float) -> float:
    """Useful model flops per device for this cell."""
    from repro.configs import cell_by_name
    cell = cell_by_name(rec["cell"])
    n_dev = rec["n_devices"]
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * active_params * tokens / n_dev
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active_params * tokens / n_dev
    return 2.0 * active_params * cell.global_batch / n_dev   # decode


def analyze(dirpath: str):
    """NOTE on loop bodies: XLA cost_analysis counts a while-loop body
    ONCE regardless of trip count, and this framework scans over layer
    periods (compile-time O(period), the production design).  Raw HLO
    flops/bytes therefore undercount by ~n_layers/period for the scanned
    portion.  We report terms from the trip-count-corrected numbers
    (raw x n_periods — a slight overcount of the non-scanned epilogue,
    so raw and corrected bracket the truth) and keep the raw ratio
    column for visibility."""
    from repro.models.lm import period_of
    rows = []
    pc_cache = {}
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        rec = json.load(open(path))
        if rec.get("compressed") or rec.get("policy") not in (
                "default", None):
            continue
        arch = rec["arch"]
        if arch not in pc_cache:
            pc_cache[arch] = _param_counts(arch)
        total, active, cfg = pc_cache[arch]
        n_periods = cfg.n_layers // period_of(cfg)
        t_c = rec["flops"] * n_periods / PEAK_FLOPS
        t_m = rec["bytes_accessed"] * n_periods / HBM_BW
        coll = sum(rec["collective_bytes"].values())
        t_x = coll * n_periods / LINK_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(rec, active)
        hlo_corr = rec["flops"] * n_periods
        ratio = mf / hlo_corr if hlo_corr > 0 else float("nan")
        t_model = mf / PEAK_FLOPS
        frac = t_model / max(t_c, t_m, t_x) if max(t_c, t_m, t_x) else 0.0
        hbm = (rec["argument_size_bytes"] or 0) + (rec["temp_size_bytes"]
                                                   or 0)
        rows.append({
            "arch": arch, "cell": rec["cell"], "mesh": rec["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom, "model_flops_ratio": ratio,
            "roofline_fraction": frac, "hbm_gib": hbm / 2 ** 30,
            "fits_hbm": hbm <= 16 * 2 ** 30,
            "lever": _LEVERS[dom],
        })
    return rows


def to_markdown(rows) -> str:
    out = ["| arch | cell | mesh | compute s | memory s | collective s | "
           "dominant | useful/HLO flops | roofline frac | HBM GiB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['dominant']} "
            f"| {r['model_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['hbm_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args(argv)
    rows = analyze(args.dir)
    md = to_markdown(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("# Roofline terms per (arch x shape x mesh)\n\n")
        f.write(f"Constants: {PEAK_FLOPS/1e12:.0f} TF/s bf16, "
                f"{HBM_BW/1e9:.0f} GB/s HBM, {LINK_BW/1e9:.0f} GB/s link. "
                "All terms are per-device seconds per step.\n\n")
        f.write(md + "\n")
    print(md)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\n# {len(rows)} cells; dominant-term counts: {doms}")
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("# five worst roofline fractions:")
    for r in worst:
        print(f"#   {r['arch']} x {r['cell']} x {r['mesh']}: "
              f"{r['roofline_fraction']:.3f} ({r['dominant']}-bound)")


if __name__ == "__main__":
    main()
