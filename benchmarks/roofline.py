"""Roofline analysis over the dry-run records (spec: ROOFLINE ANALYSIS).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs / peak_FLOPs          [s, per device]
    memory term     = HLO_bytes / HBM_bw              [s, per device]
    collective term = collective_bytes / link_bw      [s, per device]

cost_analysis / the HLO parse operate on the SPMD-partitioned per-device
module, so all three terms are already per-chip; the spec's (chips x peak)
denominator cancels.  MODEL_FLOPS uses 6*N_active*D (train), 2*N_active*D
(prefill), 2*N_active*B (decode) per device.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Writes experiments/roofline.md and prints a CSV summary.

``--check-pdgemm`` runs the collective-accounting cross-check instead:
the static per-device byte plan (``pblas.pdgemm_collective_plan``), the
compiled-HLO parse (``hlo_analysis.collective_bytes``), and the runtime
obs counters (``repro.obs``) must agree kind-for-kind on a 2x2 grid for
both pdgemm schedules — three independent derivations of the roofline's
collective term, one report.  Spawns a 4-host-device child (the
XLA_FLAGS must precede backend init); exits nonzero on any mismatch.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

import jax
import numpy as np

PEAK_FLOPS = 197e12          # TPU v5e bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_LEVERS = {
    "compute": "raise MXU utilization: larger per-device batch/seq tiles, "
               "fewer redundant (remat) flops",
    "memory": "fuse elementwise chains / increase arithmetic intensity "
              "(bigger tiles, bf16 everywhere, avoid spills)",
    "collective": "reshard to cut cross-chip traffic (more FSDP locality, "
                  "posit16-compressed wire formats, overlap with compute)",
}


def _param_counts(arch: str):
    from repro.configs import get_config
    from repro.models import init_params
    import functools
    cfg = get_config(arch)
    abstract = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
    expert = 0
    if cfg.n_experts:
        def walk(t, inmoe=False):
            nonlocal expert
            if isinstance(t, dict):
                for k, v in t.items():
                    walk(v, inmoe or k in ("w_gate", "w_up", "w_down"))
            elif isinstance(t, (list, tuple)):
                for v in t:
                    walk(v, inmoe)
            elif hasattr(t, "shape") and inmoe:
                expert += int(np.prod(t.shape))
        walk(abstract["layers"])
        active = total - expert + expert * cfg.top_k / cfg.n_experts
    else:
        active = total
    return total, active, cfg


def model_flops(rec, active_params: float) -> float:
    """Useful model flops per device for this cell."""
    from repro.configs import cell_by_name
    cell = cell_by_name(rec["cell"])
    n_dev = rec["n_devices"]
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * active_params * tokens / n_dev
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active_params * tokens / n_dev
    return 2.0 * active_params * cell.global_batch / n_dev   # decode


def analyze(dirpath: str):
    """NOTE on loop bodies: XLA cost_analysis counts a while-loop body
    ONCE regardless of trip count, and this framework scans over layer
    periods (compile-time O(period), the production design).  Raw HLO
    flops/bytes therefore undercount by ~n_layers/period for the scanned
    portion.  We report terms from the trip-count-corrected numbers
    (raw x n_periods — a slight overcount of the non-scanned epilogue,
    so raw and corrected bracket the truth) and keep the raw ratio
    column for visibility."""
    from repro.models.lm import period_of
    rows = []
    pc_cache = {}
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        rec = json.load(open(path))
        if rec.get("compressed") or rec.get("policy") not in (
                "default", None):
            continue
        arch = rec["arch"]
        if arch not in pc_cache:
            pc_cache[arch] = _param_counts(arch)
        total, active, cfg = pc_cache[arch]
        n_periods = cfg.n_layers // period_of(cfg)
        t_c = rec["flops"] * n_periods / PEAK_FLOPS
        t_m = rec["bytes_accessed"] * n_periods / HBM_BW
        coll = sum(rec["collective_bytes"].values())
        t_x = coll * n_periods / LINK_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(rec, active)
        hlo_corr = rec["flops"] * n_periods
        ratio = mf / hlo_corr if hlo_corr > 0 else float("nan")
        t_model = mf / PEAK_FLOPS
        frac = t_model / max(t_c, t_m, t_x) if max(t_c, t_m, t_x) else 0.0
        hbm = (rec["argument_size_bytes"] or 0) + (rec["temp_size_bytes"]
                                                   or 0)
        rows.append({
            "arch": arch, "cell": rec["cell"], "mesh": rec["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom, "model_flops_ratio": ratio,
            "roofline_fraction": frac, "hbm_gib": hbm / 2 ** 30,
            "fits_hbm": hbm <= 16 * 2 ** 30,
            "lever": _LEVERS[dom],
        })
    return rows


def to_markdown(rows) -> str:
    out = ["| arch | cell | mesh | compute s | memory s | collective s | "
           "dominant | useful/HLO flops | roofline frac | HBM GiB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | {r['dominant']} "
            f"| {r['model_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['hbm_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(out)


# --------------------------------------------------------------------------
# collective-accounting cross-check (static plan vs HLO vs runtime obs)
# --------------------------------------------------------------------------

_PDGEMM_CHECK = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.core import posit
from repro.core.formats import P32E2
from repro.dist import layout, pblas
from repro.launch import hlo_analysis

n, nb = {n}, {nb}
mesh = jax.make_mesh((2, 2), ("row", "col"))
rng = np.random.default_rng(0)
a_p = posit.from_float64(jnp.asarray(rng.standard_normal((n, n))))
b_p = posit.from_float64(jnp.asarray(rng.standard_normal((n, n))))
A = layout.distribute(a_p, mesh, nb)
B = layout.distribute(b_p, mesh, nb)
lay = A.layout
sharding = jax.sharding.NamedSharding(mesh, pblas._SPEC)
c0 = jax.device_put(jnp.zeros((lay.p * lay.lm, lay.q * lay.ln), jnp.int32),
                    sharding)

out = []
for k_split, backend in ((False, "xla_quire"), (True, "quire_exact")):
    plan = pblas.pdgemm_collective_plan(lay, lay, k_split=k_split)
    hlo = hlo_analysis.collective_bytes(
        pblas._pdgemm_sharded.lower(
            A.data, B.data, c0, lay_a=lay, lay_b=lay, mesh=mesh,
            alpha=1.0, beta=0.0, backend=backend, k_split=k_split,
            fmt=P32E2).compile().as_text())
    with obs.scoped() as m:
        pblas.pdgemm(A, B, backend=backend, k_split=k_split)
    pre = "dist.pdgemm."
    runtime = {{k[len(pre):-len(".bytes")]: int(v)
               for k, v in m.to_dict()["counters"].items()
               if k.startswith(pre) and k.endswith(".bytes")}}
    out.append({{"schedule": "k_split" if k_split else "owner-computes",
                "backend": backend, "plan": plan, "hlo": hlo,
                "runtime": runtime}})
print("CHECK_JSON " + json.dumps(out))
"""


def check_pdgemm(n: int = 64, nb: int = 16) -> int:
    """Run the three-way pdgemm collective-byte cross-check on a 2x2
    grid (4 forced host devices, fresh interpreter) and print one
    roofline-style report.  Returns a process exit code."""
    code = _PDGEMM_CHECK.format(n=n, nb=nb)
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", "")).strip()
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        print(f"check child failed:\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}",
              file=sys.stderr)
        return 1
    rows = None
    for line in r.stdout.splitlines():
        if line.startswith("CHECK_JSON "):
            rows = json.loads(line[len("CHECK_JSON "):])
    if rows is None:
        print("no CHECK_JSON in child output", file=sys.stderr)
        return 1

    print(f"# pdgemm collective accounting, n={n} nb={nb}, 2x2 grid "
          "(per-device bytes)\n")
    print("| schedule | collective | plan B | HLO B | runtime B | agree |")
    print("|---|---|---|---|---|---|")
    ok = True
    for row in rows:
        kinds = sorted(set(row["plan"]) | set(row["hlo"])
                       | set(row["runtime"]))
        for kind in kinds:
            p = row["plan"].get(kind, 0)
            h = row["hlo"].get(kind, 0)
            u = row["runtime"].get(kind, 0)
            agree = p == h == u
            ok &= agree
            print(f"| {row['schedule']} | {kind} | {p} | {h} | {u} "
                  f"| {'Y' if agree else 'MISMATCH'} |")
        total = sum(row["plan"].values())
        print(f"| {row['schedule']} | **total** | {total} |  |  | "
              f"t_coll = {total / LINK_BW:.2e} s |")
    print(f"\n{'AGREE' if ok else 'MISMATCH'}: static plan vs compiled HLO "
          "vs runtime obs counters")
    return 0 if ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--check-pdgemm", action="store_true",
                    help="cross-check pdgemm collective bytes (plan vs "
                         "HLO vs runtime obs) on a 2x2 grid and exit")
    args = ap.parse_args(argv)
    if args.check_pdgemm:
        raise SystemExit(check_pdgemm())
    rows = analyze(args.dir)
    md = to_markdown(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("# Roofline terms per (arch x shape x mesh)\n\n")
        f.write(f"Constants: {PEAK_FLOPS/1e12:.0f} TF/s bf16, "
                f"{HBM_BW/1e9:.0f} GB/s HBM, {LINK_BW/1e9:.0f} GB/s link. "
                "All terms are per-device seconds per step.\n\n")
        f.write(md + "\n")
    print(md)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\n# {len(rows)} cells; dominant-term counts: {doms}")
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("# five worst roofline fractions:")
    for r in worst:
        print(f"#   {r['arch']} x {r['cell']} x {r['mesh']}: "
              f"{r['roofline_fraction']:.3f} ({r['dominant']}-bound)")


if __name__ == "__main__":
    main()
