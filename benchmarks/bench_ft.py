"""ABFT overhead benchmark: checksum-protected vs unprotected paths.

Three families, each with **bit-identity asserted before any timing**
(the protected path's whole contract is that fault-free words equal the
unprotected words exactly, and recovered words equal fault-free words
exactly — a mismatch is a bug, not noise):

* ``rgemm_ft``   — quire-checksummed GEMM vs plain ``rgemm``,
                   fault-free and with one injected word flip (the
                   1-fault row times detection + one retry)
* ``rgetrf_ft``  — protected host-stepped blocked LU vs the frozen
                   single-dispatch ``rgetrf`` (acceptance target:
                   <= 1.3x fault-free overhead at n=512)
* ``pdgemm_ft``  — strip-checksummed distributed GEMM vs ``pdgemm`` on
                   a forced-host-device grid (subprocess child, the
                   bench_dist.py pattern)

``--soak N`` (the nightly fault-injection soak) runs N seeded random
injections per site across every protected driver and ASSERTS 100%
detection with bit-identical recovery; the soak tally rides along as
rows so the artifact records the evidence.

Writes ``BENCH_ft.json`` (schema: {meta, results: [{name, config,
t_old_ms (unprotected), t_new_ms (protected), speedup, overhead,
identical}]}) — merged by merge_bench.py next to the other BENCH files.
Read ``overhead`` (= protected/unprotected) directly; ``speedup`` keeps
the shared merge schema (old/new ratio, < 1 here by construction).
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from bench_decomp import _identical, _time_pair
from repro import ft
from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.lapack import decomp, qr


def _posit_matrix(rng, shape, lo=-4, hi=4):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return P.from_float64(jnp.asarray(x))


def _row(name, config, t_old, t_new, identical, results):
    r = {"name": name, "config": config, "t_old_ms": round(t_old, 3),
         "t_new_ms": round(t_new, 3), "speedup": round(t_old / t_new, 3),
         "overhead": round(t_new / t_old, 3), "identical": identical}
    results.append(r)
    flag = "" if identical else "  << MISMATCH"
    print(f"{name:<12} {config:<30} plain {t_old:8.1f}ms  ft {t_new:8.1f}ms"
          f"  {r['overhead']:5.2f}x overhead{flag}", flush=True)
    assert identical, f"{name} {config}: protected path not bit-identical"
    return r


def bench_rgemm_ft(results, quick, reps):
    rng = np.random.default_rng(0)
    n = 96 if quick else 256
    a, b = _posit_matrix(rng, (n, n)), _posit_matrix(rng, (n, n))
    ref = rgemm(a, b)
    got, _, rep = ft.rgemm_ft(a, b)
    assert rep.detections == 0
    t_old, t_new = _time_pair(lambda: rgemm(a, b),
                              lambda: ft.rgemm_ft(a, b)[0], reps)
    _row("rgemm_ft", f"n={n} fault-free", t_old, t_new,
         _identical(got, ref), results)

    plan = ft.make_plan(1, "rgemm.out", size=n * n)
    got, _, rep = ft.rgemm_ft(a, b, plan=plan)
    assert rep.detections == 1
    t_old, t_new = _time_pair(lambda: rgemm(a, b),
                              lambda: ft.rgemm_ft(a, b, plan=plan)[0], reps)
    _row("rgemm_ft", f"n={n} 1-fault", t_old, t_new,
         _identical(got, ref), results)


def bench_rgetrf_ft(results, quick, reps):
    rng = np.random.default_rng(1)
    n, nb = (96, 32) if quick else (512, 64)
    a = _posit_matrix(rng, (n, n))
    ref = decomp.rgetrf(a, nb=nb)
    lu, piv, rep = decomp.rgetrf_ft(a, nb=nb)
    assert rep.detections == 0
    t_old, t_new = _time_pair(lambda: decomp.rgetrf(a, nb=nb),
                              lambda: decomp.rgetrf_ft(a, nb=nb)[0], reps)
    _row("rgetrf_ft", f"n={n} nb={nb} fault-free", t_old, t_new,
         _identical((lu, piv), ref), results)

    plan = ft.make_plan(2, "rgetrf.step", size=n * nb, steps=n // nb)
    lu, piv, rep = decomp.rgetrf_ft(a, nb=nb, plan=plan)
    assert rep.detections >= 1
    t_old, t_new = _time_pair(
        lambda: decomp.rgetrf(a, nb=nb),
        lambda: decomp.rgetrf_ft(a, nb=nb, plan=plan)[0], reps)
    _row("rgetrf_ft", f"n={n} nb={nb} 1-fault", t_old, t_new,
         _identical((lu, piv), ref), results)


_CHILD = r"""
import json, sys
import numpy as np, jax, jax.numpy as jnp
sys.path.insert(0, {bench_dir!r})
from bench_decomp import _time_pair, _identical
from repro.core import posit as P
from repro.dist import distribute, make_grid_mesh, pdgemm
from repro.dist.pblas import pdgemm_ft

quick = {quick!r}
p, q = {grid!r}
mesh = make_grid_mesh(p, q)
n = 96 if quick else 192
reps = 3 if quick else 6
rng = np.random.default_rng(0)
def pm(shape):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(-4, 4, shape))
    return P.from_float64(jnp.asarray(x))

a, b = pm((n, n)), pm((n, n))
ad, bd = distribute(a, mesh, 32), distribute(b, mesh, 32)
ref = pdgemm(ad, bd)
got, rep = pdgemm_ft(ad, bd)
assert rep.detections == 0
ident = _identical(got.gather(), ref.gather())
t_old, t_new = _time_pair(lambda: pdgemm(ad, bd).data,
                          lambda: pdgemm_ft(ad, bd)[0].data, reps)
rows = [{{"name": "pdgemm_ft", "config": f"n={{n}} fault-free",
          "devices": p * q, "grid": f"{{p}}x{{q}}",
          "t_old_ms": round(t_old, 3), "t_new_ms": round(t_new, 3),
          "speedup": round(t_old / t_new, 3),
          "overhead": round(t_new / t_old, 3), "identical": ident}}]
print("ROWS_JSON " + json.dumps(rows))
"""


def bench_pdgemm_ft(results, quick, bench_dir):
    devices = 4 if quick else 8
    grid = (2, 2) if quick else (2, 4)
    code = _CHILD.format(bench_dir=bench_dir, quick=quick, grid=grid)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    src = os.path.abspath(os.path.join(bench_dir, "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"pdgemm_ft child failed:\n{r.stdout[-2000:]}\n"
                           f"{r.stderr[-4000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROWS_JSON "):
            for row in json.loads(line[len("ROWS_JSON "):]):
                assert row["identical"], "pdgemm_ft not bit-identical"
                results.append(row)
                print(f"{row['name']:<12} {row['config']:<30} "
                      f"plain {row['t_old_ms']:8.1f}ms  "
                      f"ft {row['t_new_ms']:8.1f}ms  "
                      f"{row['overhead']:5.2f}x overhead", flush=True)
            return
    raise RuntimeError("pdgemm_ft child: no ROWS_JSON in output")


def soak(results, n_inject, quick):
    """N seeded random injections per site across every protected
    driver: ASSERTS 100% detection and bit-identical recovery, then
    records the tally as bench rows (the nightly fault-injection
    soak)."""
    rng = np.random.default_rng(3)
    n, nb = (48, 16) if quick else (96, 32)
    a = _posit_matrix(rng, (n, n))
    spd = rgemm(a, a, trans_b=True)
    tall = _posit_matrix(rng, (n, nb * 2))
    def run_rgemm(plan):
        c, _, rep = ft.rgemm_ft(a, a, plan=plan)
        return (c,), rep

    def run_qgemm(plan):
        c, _, rep = ft.quire_gemm_ft(a, a, plan=plan)
        return (c,), rep

    def run_getrf(plan):
        lu, piv, rep = decomp.rgetrf_ft(a, nb=nb, plan=plan)
        return (lu, piv), rep

    def run_potrf(plan):
        l, rep = decomp.rpotrf_ft(spd, nb=nb, plan=plan)
        return (l,), rep

    def run_geqrf(plan):
        r, tau, rep = qr.rgeqrf_ft(tall, nb=nb, plan=plan)
        return (r, tau), rep

    word_kinds = ("flip", "nar", "saturate")
    # site -> (runner, reference, lane count, steps, fault nbits, kinds);
    # limb-plane faults are bit flips only (nar/saturate are word-domain)
    cases = {
        "rgemm.out": (run_rgemm, (rgemm(a, a),), n * n, 1, 32, word_kinds),
        "rgemm.limbs": (run_qgemm,
                        (rgemm(a, a, backend="quire_exact"),),
                        n * n, 1, 64, ("flip",)),
        "rgetrf.step": (run_getrf, decomp.rgetrf(a, nb=nb),
                        n * nb, n // nb, 32, word_kinds),
        "rpotrf.step": (run_potrf, (decomp.rpotrf(spd, nb=nb),),
                        n * nb, n // nb, 32, word_kinds),
        "rgeqrf.step": (run_geqrf, qr.rgeqrf(tall, nb=nb),
                        n * nb, 2, 32, word_kinds),
    }
    for site, (run, ref, size, steps, nbits, kinds) in cases.items():
        injected = detected = recovered = 0
        for seed in range(n_inject):
            plan = ft.make_plan(seed, site, size=size, steps=steps,
                                kinds=kinds, nbits=nbits)
            out, rep = run(plan)
            injected += 1
            detected += 1 if rep.detections >= 1 else 0
            recovered += 1 if _identical(out, ref) else 0
        row = {"name": "soak", "config": f"{site} x{n_inject}",
               "injected": injected, "detected": detected,
               "recovered": recovered,
               "identical": detected == injected == recovered}
        results.append(row)
        print(f"soak {site:<14} injected {injected}  detected {detected}"
              f"  recovered {recovered}", flush=True)
        assert detected == injected, f"{site}: missed detections"
        assert recovered == injected, f"{site}: non-identical recovery"


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small sizes / fewer reps (CI perf-smoke)")
    parser.add_argument("--soak", type=int, default=0, metavar="N",
                        help="also run N seeded injections per site and "
                             "assert 100%% detection (nightly)")
    parser.add_argument("--out", default="BENCH_ft.json")
    args = parser.parse_args(argv)
    reps = 3 if args.quick else 5
    bench_dir = os.path.dirname(os.path.abspath(__file__))

    results = []
    bench_rgemm_ft(results, args.quick, reps)
    bench_rgetrf_ft(results, args.quick, reps)
    bench_pdgemm_ft(results, args.quick, bench_dir)
    if args.soak:
        soak(results, args.soak, args.quick)

    payload = {
        "meta": {
            "bench": "bench_ft", "quick": args.quick,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "note": ("overhead = protected/unprotected wall-clock; "
                     "identity is the gate, timings are trajectory"),
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(results)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
