"""Distributed linear-algebra scaling sweep: 1/2/4/8 host devices.

For each device count D this script spawns a fresh interpreter with
``--xla_force_host_platform_device_count=D`` (the flag must precede
backend init) and, inside it:

1. **asserts bit-identity first** — ``pdgemm`` / ``p_rpotrf`` /
   ``p_rgetrf`` words equal the single-device ``rgemm`` / ``rpotrf`` /
   ``rgetrf`` words on the D-device grid (plus the 1x8 degenerate grid
   at D=8, per the acceptance criteria) — no timing is reported for a
   mismatching configuration;
2. times dist vs single-device with the **interleaved best-of-N**
   estimator (``bench_decomp._time_pair``): this box is 2 vCPUs with
   ±2x host drift, so alternating the two programs rep-by-rep is the
   only way the ratio means anything.

Writes ``BENCH_dist.json`` (schema: {meta, results: [{name, config,
devices, grid, t_single_ms, t_dist_ms, speedup, identical}]}) — uploaded
by CI perf-smoke next to BENCH_decomp.json.

Read the numbers as *trajectory data*: D forced host devices on 2 real
cores time-slice the same silicon, so wall-clock "speedup" here mostly
measures the dist schedule's overhead (gathers, masked updates), not
scaling; on a real multi-chip mesh the same program distributes the
O(n³) trailing work P*Q ways.  Identity is the acceptance gate.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys

def _grid_for(d: int) -> tuple[int, int]:
    """Most-square P x Q factoring of d (largest divisor <= sqrt(d))."""
    p = max(f for f in range(1, int(d ** 0.5) + 1) if d % f == 0)
    return p, d // p

_CHILD = r"""
import json, sys
import numpy as np, jax, jax.numpy as jnp
sys.path.insert(0, {bench_dir!r})
from bench_decomp import _time_pair, _identical
from repro import obs
from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.lapack import decomp
from repro.dist import distribute, make_grid_mesh, pdgemm, p_rpotrf, p_rgetrf

quick = {quick!r}
devices = {devices!r}
p, q = {grid!r}
mesh = make_grid_mesh(p, q)
nb = 32
n = 96 if quick else 192
reps = 3 if quick else 6
rng = np.random.default_rng(0)

def pm(shape, lo=-4, hi=4):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return P.from_float64(jnp.asarray(x))

rows = []
def row(name, config, single_fn, dist_fn, ident):
    assert ident, f"{{name}} {{config}}: dist path is not bit-identical"
    t_s, t_d = _time_pair(single_fn, dist_fn, reps)
    with obs.scoped() as m:            # un-timed observed re-run: the
        jax.block_until_ready(dist_fn())   # collective-byte counters
    rows.append({{"name": name, "config": config, "devices": devices,
                 "grid": [p, q], "t_single_ms": round(t_s, 3),
                 "t_dist_ms": round(t_d, 3),
                 "speedup": round(t_s / t_d, 3), "identical": True,
                 "metrics": m.bench_block()}})

# pdgemm
a, b = pm((n, n)), pm((n, n))
ad, bd = distribute(a, mesh, nb), distribute(b, mesh, nb)
for backend in ("xla_quire", "quire_exact"):
    ref = rgemm(a, b, backend=backend)
    got = pdgemm(ad, bd, backend=backend)
    row("pdgemm", f"{{n}}^3 nb={{nb}} {{backend}}",
        lambda: rgemm(a, b, backend=backend),
        lambda: pdgemm(ad, bd, backend=backend).data,
        _identical(got.gather(), ref))

# factorizations (xla_quire: the fast CPU trailing-update path)
g = rng.standard_normal((n, n))
sp = P.from_float64(jnp.asarray(g.T @ g + n * np.eye(n)))
gp = P.from_float64(jnp.asarray(g))
spd, gpd = distribute(sp, mesh, nb), distribute(gp, mesh, nb)
ref_l = decomp.rpotrf(sp, nb=nb)
got_l = p_rpotrf(spd)
row("p_rpotrf", f"n={{n}} nb={{nb}} xla_quire",
    lambda: decomp.rpotrf(sp, nb=nb), lambda: p_rpotrf(spd).data,
    _identical(got_l.gather(), ref_l))
ref_lu = decomp.rgetrf(gp, nb=nb)
got_lu = p_rgetrf(gpd)
row("p_rgetrf", f"n={{n}} nb={{nb}} xla_quire",
    lambda: decomp.rgetrf(gp, nb=nb),
    lambda: p_rgetrf(gpd)[0].data,
    _identical((got_lu[0].gather(), got_lu[1]), ref_lu))

if devices == 8:
    # acceptance: the 1x8 degenerate grid is also bit-identical
    m18 = make_grid_mesh(1, 8)
    ok = (_identical(pdgemm(distribute(a, m18, nb), distribute(b, m18, nb),
                            backend="quire_exact").gather(),
                     rgemm(a, b, backend="quire_exact"))
          and _identical(p_rpotrf(distribute(sp, m18, nb)).gather(), ref_l))
    assert ok, "1x8 grid not bit-identical"
    rows.append({{"name": "identity_1x8", "config": f"n={{n}} nb={{nb}}",
                 "devices": 8, "grid": [1, 8], "t_single_ms": 0.0,
                 "t_dist_ms": 0.0, "speedup": 1.0, "identical": True}})

print("ROWS_JSON " + json.dumps(rows))
"""


def run_child(devices: int, quick: bool, bench_dir: str):
    code = _CHILD.format(quick=quick, devices=devices,
                         grid=_grid_for(devices), bench_dir=bench_dir)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    src = os.path.abspath(os.path.join(bench_dir, "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"devices={devices} child failed:\n"
                           f"{r.stdout[-2000:]}\n{r.stderr[-4000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROWS_JSON "):
            return json.loads(line[len("ROWS_JSON "):])
    raise RuntimeError(f"devices={devices}: no ROWS_JSON in output")


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small sizes / fewer reps (CI perf-smoke)")
    parser.add_argument("--devices", default="1,2,4,8",
                        help="comma-separated host-device counts")
    parser.add_argument("--out", default="BENCH_dist.json")
    args = parser.parse_args(argv)
    bench_dir = os.path.dirname(os.path.abspath(__file__))

    results = []
    for d in (int(x) for x in args.devices.split(",")):
        rows = run_child(d, args.quick, bench_dir)
        for r in rows:
            results.append(r)
            print(f"{r['name']:<12} {r['config']:<26} D={r['devices']} "
                  f"grid={r['grid']}  single {r['t_single_ms']:8.1f}ms  "
                  f"dist {r['t_dist_ms']:8.1f}ms  {r['speedup']:5.2f}x",
                  flush=True)

    payload = {
        "meta": {"bench": "bench_dist", "quick": args.quick,
                 "platform": platform.platform(),
                 "python": platform.python_version(),
                 "note": ("host devices time-slice the same cores; "
                          "identity is the gate, timings are trajectory")},
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(results)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
