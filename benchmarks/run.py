# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks.paper_tables import ALL_BENCHES
    print("name,us_per_call,derived")
    for bench in ALL_BENCHES:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived}", flush=True)
    print(f"# total bench wall time: {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
