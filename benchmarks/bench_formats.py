"""Format sweep benchmark: accuracy across posit widths + the
mixed-precision speed play, with p32e2 bit-identity to PR 3 asserted
before any number is reported.

Four sections, one BENCH_formats.json:

* ``golden``    — the PR-3 golden-hash gate: every p32e2 path (rgemm
                  backends, rpotrf/rgetrf, quire IR) must produce words
                  bit-identical to the pre-format-parametric tree on
                  fixed seeds (same pins as tests/test_formats.py).  A
                  mismatch aborts the benchmark — accuracy/speed numbers
                  for a silently-changed p32e2 are worthless.
* ``accuracy``  — the paper's §5.1 sigma-grid protocol per format
                  (p32e2 / p16e1 / p8e2): digits vs binary32.  This is
                  the Ciocirlan-style width sweep the format-parametric
                  stack opens.
* ``mixed``     — rgesv_mp / rposv_mp digits_lost vs full-width IR on
                  the sigma grid (the accuracy half of the HPL-AI trade:
                  ~0 wherever the mp loop converges).
* ``timing``    — rgetrf p16e1 vs p32e2 (quire_exact backend, n=512
                  full / 128 quick) and the isolated trailing-update
                  quire_gemm per format.  Interleaved best-of-N (host
                  drift cancels out of the ratio).  In this CPU
                  emulation the only format-dependent cost is the quire
                  limb count (4 limbs for p16e1 vs 16 for p32e2), so the
                  end-to-end factorization gains ~1.2-1.3x (panels/trsm
                  are format-independent f64 chains) while the isolated
                  quire update gains ~3-4x; on real hardware the narrow
                  format's 2x memory-bandwidth win applies to every
                  stage.

Schema: {meta, results: [{section, name, config, ...}]}; the CI
perf-smoke job uploads it and benchmarks/merge_bench.py folds it into
BENCH_summary.json + the step-summary trajectory table.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import platform
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posit as P
from repro.core.formats import P16E1, P32E2, P8E2
from repro.kernels.ops import rgemm
from repro.lapack import decomp, error_eval, refine, solve
from repro.quire.gemm import quire_gemm

# Same pins as tests/test_formats.py::GOLDEN_P32 (captured from the PR-3
# tree, commit 59ee04b, on these exact seeds) — duplicated here so the
# benchmark is self-contained when run outside the test tree.
GOLDEN_P32 = {
    "rgemm_xla_quire": "7c1a480e5c9a7d8c",
    "rgemm_quire_exact": "7c1a480e5c9a7d8c",
    "rgemm_faithful": "7a55e20adb994b6a",
    "rgemm_pallas_split3": "3fd3e072ff75b648",
    "rgemm_ab1": "e0d80ac10820c8d9",
    "rpotrf": "7e9165ec6ef12151",
    "rgetrf": "07c2e4fd338ae084",
    "rgetrs_q": "895d2a22713a1d75",
    "rgesv_ir": "d16b0c99d17ea97f",
    "rposv_ir": "42dd7e9cbf36c6c2",
}


def _h(*arrs):
    m = hashlib.sha256()
    for a in arrs:
        m.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return m.hexdigest()[:16]


# the one interleaved best-of-N estimator (alternating reps so host
# drift cancels out of the ratio) — shared, not copied, so any retuning
# keeps every bench measuring with the same methodology
from bench_decomp import _attach_metrics, _time_pair  # noqa: E402


def gate_golden(results):
    """Assert every p32e2 path is bit-identical to PR 3 BEFORE timing."""
    rng = np.random.default_rng(42)
    a64 = rng.standard_normal((48, 48))
    s64 = a64.T @ a64
    b64 = rng.standard_normal(48)
    ap = P.from_float64(jnp.asarray(a64))
    sp = P.from_float64(jnp.asarray(s64))
    bp = P.from_float64(jnp.asarray(b64))

    got = {}
    for bk in ("xla_quire", "quire_exact", "faithful", "pallas_split3"):
        got[f"rgemm_{bk}"] = _h(rgemm(ap, ap, backend=bk))
    got["rgemm_ab1"] = _h(rgemm(ap, ap, sp, alpha=-1.0, beta=1.0,
                                backend="quire_exact"))
    got["rpotrf"] = _h(decomp.rpotrf(sp, nb=16))
    lu, piv = decomp.rgetrf(ap, nb=16)
    got["rgetrf"] = _h(lu, piv)
    got["rgetrs_q"] = _h(solve.rgetrs(lu, piv, bp, quire=True))
    (xh, xl), _ = refine.rgesv_ir(ap, bp, iters=2, nb=16)
    got["rgesv_ir"] = _h(xh, xl)
    (yh, yl), _ = refine.rposv_ir(sp, bp, iters=2, nb=16)
    got["rposv_ir"] = _h(yh, yl)

    bad = {k: (v, GOLDEN_P32[k]) for k, v in got.items()
           if v != GOLDEN_P32[k]}
    ok = not bad
    results.append({"section": "golden", "name": "p32e2_bit_identity",
                    "config": "PR-3 pins, seed 42", "identical": ok,
                    "mismatches": sorted(bad)})
    print(f"golden p32e2 bit-identity vs PR 3: "
          f"{'OK' if ok else f'MISMATCH {bad}'}", flush=True)
    assert ok, f"p32e2 words changed vs PR 3: {bad}"


def bench_accuracy(results, quick):
    n = 32 if quick else 96
    sigmas = (1.0,) if quick else (1e-2, 1.0, 1e2)
    for fmt in (P32E2, P16E1, P8E2):
        for sigma in sigmas:
            r = error_eval.backward_error_study(
                n, sigma, "lu", nb=16, gemm_backend="xla_quire", fmt=fmt)
            results.append({
                "section": "accuracy", "name": "sigma_grid_lu",
                "config": f"{fmt.name} n={n} sigma={sigma:g}",
                "e_posit": r.e_posit, "e_binary32": r.e_binary32,
                "digits_vs_b32": round(r.digits, 3)})
            print(f"accuracy {fmt.name:6s} sigma={sigma:<8g} "
                  f"e_posit={r.e_posit:.3e}  digits vs b32 "
                  f"{r.digits:+.2f}", flush=True)


def bench_mixed(results, quick):
    # LU: the acceptance grid — the A-equilibrated rgesv_mp is sigma-
    # invariant, so every cell must reach the rgesv_ir floor.  Cholesky:
    # the §5.1 SPD ensemble's condition number is cond(X)^2, which at
    # n=64 already pushes rho = cond * eps_p16e1 toward 1 (the mp
    # convergence envelope, DESIGN.md §8) — the SPD cell runs at n=48,
    # inside the envelope, matching tests/test_formats.py.
    n_lu = 32 if quick else 64
    sigmas = (1.0,) if quick else (1e-2, 1.0, 1e2)
    cells = [("lu", n_lu, s) for s in sigmas]
    if not quick:
        cells.append(("cholesky", 48, 1.0))
    for algo, n, sigma in cells:
        r = error_eval.mixed_precision_study(n, sigma, algo, nb=16)
        results.append({
            "section": "mixed", "name": f"rgesv_mp_{algo}",
            "config": f"n={n} sigma={sigma:g}",
            "e_ir": r.e_ir, "e_mp": r.e_mp,
            "digits_lost": round(r.digits_lost, 3)})
        print(f"mixed {algo:8s} n={n} sigma={sigma:<8g} e_ir={r.e_ir:.2e} "
              f"e_mp={r.e_mp:.2e}  digits lost "
              f"{r.digits_lost:+.2f}", flush=True)
        assert r.digits_lost < 0.5, (
            f"mp refinement failed to reach the IR floor: {r}")


def bench_timing(results, quick, reps):
    rng = np.random.default_rng(7)
    n = 128 if quick else 512
    nb = 32 if quick else 64
    a64 = rng.standard_normal((n, n))
    ap32 = P.from_float64(jnp.asarray(a64), P32E2)
    ap16 = P.from_float64(jnp.asarray(a64), P16E1)

    # the mp factorization step: p16e1 rgetrf vs p32e2 rgetrf, quire
    # trailing updates (the format-dependent cost in this emulation)
    f32 = lambda: decomp.rgetrf(ap32, nb=nb, gemm_backend="quire_exact",
                                fmt=P32E2)
    f16 = lambda: decomp.rgetrf(ap16, nb=nb, gemm_backend="quire_exact",
                                fmt=P16E1)
    t32, t16 = _time_pair(f32, f16, reps)
    speedup = t32 / t16
    # no per-row "identical" flag: the two sides are different formats by
    # construction; the bit-identity gate for this bench is the golden
    # p32e2 preflight (gate_golden), which already ran or we never got here
    results.append(_attach_metrics({
        "section": "timing", "name": "rgetrf_factor_fmt",
        "config": f"n={n} nb={nb} quire_exact p16e1 vs p32e2",
        "t_old_ms": round(t32, 3), "t_new_ms": round(t16, 3),
        "speedup": round(speedup, 3)}, f16))
    print(f"timing rgetrf n={n}: p32e2 {t32:8.1f}ms  p16e1 {t16:8.1f}ms  "
          f"{speedup:5.2f}x", flush=True)
    # The acceptance gate lives on the full n=512 run; the quick (CI)
    # leg's n=128 factorization is panel-dominated and its ~1.1x sits
    # inside shared-runner drift, so it reports trajectory only.
    if not quick:
        assert speedup > 1.05, (
            f"p16e1 factorization not measurably faster: {speedup:.3f}x")

    # isolated trailing-update shape: where the limb-count win lives
    m = 48 if quick else 64
    k = 128 if quick else 256
    a16 = P.from_float64(jnp.asarray(rng.standard_normal((m, k))), P16E1)
    b16 = P.from_float64(jnp.asarray(rng.standard_normal((k, m))), P16E1)
    a32 = P.from_float64(jnp.asarray(rng.standard_normal((m, k))), P32E2)
    b32 = P.from_float64(jnp.asarray(rng.standard_normal((k, m))), P32E2)
    g32 = lambda: quire_gemm(a32, b32, fmt=P32E2)
    g16 = lambda: quire_gemm(a16, b16, fmt=P16E1)
    t32g, t16g = _time_pair(g32, g16, reps)
    results.append({
        "section": "timing", "name": "quire_gemm_fmt",
        "config": f"{m}x{k}x{m} p16e1 (4 limbs) vs p32e2 (16 limbs)",
        "t_old_ms": round(t32g, 3), "t_new_ms": round(t16g, 3),
        "speedup": round(t32g / t16g, 3)})
    print(f"timing quire_gemm {m}x{k}x{m}: p32e2 {t32g:8.1f}ms  "
          f"p16e1 {t16g:8.1f}ms  {t32g / t16g:5.2f}x", flush=True)


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small sizes / fewer reps (CI perf-smoke)")
    parser.add_argument("--out", default="BENCH_formats.json")
    args = parser.parse_args(argv)
    # min-of-N needs enough reps that both sides of a pair sample the
    # fast scheduler mode on small shared boxes (bimodal ~2.5x swings
    # observed on 2-vCPU hosts); the quick gate is report-only anyway.
    reps = 5 if args.quick else 6

    results = []
    gate_golden(results)            # MUST pass before any timing
    bench_accuracy(results, args.quick)
    bench_mixed(results, args.quick)
    bench_timing(results, args.quick, reps)

    payload = {
        "meta": {
            "bench": "bench_formats", "quick": args.quick,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(results)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
