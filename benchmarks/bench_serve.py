"""Serving benchmark: posit-quantized continuous-batching throughput,
with batched-vs-sequential bit-identity asserted before any number is
reported.

Four sections, one BENCH_serve.json:

* ``gate``    — the correctness preflight: on tiny qwen2 (attention)
                and mamba2 (SSM) models with p16e1-quantized weights
                and a p16e1 paged KV-cache, the batched engine
                (``max_inflight=3``) must emit token streams
                bit-identical to the sequential reference
                (``max_inflight=1`` — the SAME jitted program at the
                same static width, so row contents are provably
                independent).  A mismatch aborts the benchmark —
                throughput numbers for a decode that reorders results
                are worthless.
* ``replay``  — synthetic-traffic replay (seeded arrivals, Poisson
                lengths) per storage format x batch size: wall-clock
                tokens/sec, requests/sec, mean batch occupancy.  The
                f32 leg of each batch size is the ``t_old_ms``
                reference; on this CPU emulation posit decode adds
                compute, so these rows are trajectory data — the
                posit win here is storage (below), the speed win is
                real only where narrow HBM traffic pays.
* ``storage`` — the HBM evidence, asserted: posit weight words are
                >= 2x smaller than their f32 equivalent (exactly 2x
                p16e1, 4x p8e2 — wire-width ratios) and the p16e1 KV
                pool is >= 2x smaller than the f32 pool it replaces.
* ``study``   — quant_study accuracy rows (rel_err / KL perplexity
                proxy / top-1 agreement / golden-zone occupancy) per
                arch x format x equilibration, bf16 reference row
                included; printed as a markdown table for the nightly
                step summary.

Schema: {meta, results: [{section, name, config, ...}]}; replay rows
carry ``tok_s`` which benchmarks/merge_bench.py surfaces as ``N tok/s``
in the trajectory table.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys

import jax
import numpy as np

from repro.configs import get_tiny_config
from repro.models import init_params
from repro.serving import (Engine, QuantConfig, TrafficConfig,
                           param_bytes, quantize_params, replay,
                           synth_trace)
from repro.serving.study import quant_study, study_table

GATE_ARCHS = ("qwen2-0.5b", "mamba2-780m")
SEED = 0


def _params(arch):
    cfg = get_tiny_config(arch, policy="f32")
    return cfg, init_params(jax.random.PRNGKey(SEED), cfg)


def _engine(params, cfg, *, batch, kv_fmt, inflight=None):
    return Engine(params, cfg, max_batch=batch, page_size=16,
                  max_seq=128, kv_fmt=kv_fmt, max_inflight=inflight)


def gate_identity(results):
    """Assert batched == sequential decode BEFORE timing anything."""
    for arch in GATE_ARCHS:
        cfg, params = _params(arch)
        qp = quantize_params(params, QuantConfig(fmt="p16e1"))
        trace = synth_trace(TrafficConfig(n_requests=5, mean_plen=8,
                                          mean_new=6, vocab=cfg.vocab,
                                          seed=SEED))
        reqs = [type(r)(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                for r in trace]                      # arrival-free copy
        batched = _engine(qp, cfg, batch=3, kv_fmt="p16e1").run(reqs)
        seq = _engine(qp, cfg, batch=3, kv_fmt="p16e1",
                      inflight=1).run(reqs)
        ok = (sorted(batched) == sorted(seq)
              and all(np.array_equal(batched[k], seq[k]) for k in batched))
        results.append({"section": "gate",
                        "name": "batched_vs_sequential",
                        "config": f"{cfg.name} w=p16e1 kv=p16e1 b=3",
                        "identical": bool(ok)})
        print(f"gate {cfg.name}: batched == sequential: "
              f"{'OK' if ok else 'MISMATCH'}", flush=True)
        assert ok, f"batched decode diverged from sequential on {arch}"


# storage-format legs: (label, weight fmt | None, kv fmt | None).  The
# p8e2 leg is weights-only — 8-bit KV loses too much positional signal
# to be the default, but 4x-smaller weights stand on their own.
FMT_LEGS = (("f32", None, None),
            ("p16e1", "p16e1", "p16e1"),
            ("p8e2_w", "p8e2", None))


def bench_replay(results, quick, reps):
    archs = ("qwen2-0.5b",) if quick else ("qwen2-0.5b", "mamba2-780m")
    batches = (4,) if quick else (2, 4, 8)
    tc = TrafficConfig(n_requests=6 if quick else 16,
                       mean_plen=8 if quick else 12,
                       mean_new=4 if quick else 8, seed=SEED)
    for arch in archs:
        cfg, params = _params(arch)
        tc_a = TrafficConfig(**{**tc.__dict__, "vocab": cfg.vocab})
        legs = {}
        for label, wfmt, kfmt in FMT_LEGS:
            p = (quantize_params(params, QuantConfig(fmt=wfmt))
                 if wfmt else params)
            legs[label] = (p, kfmt)
        for batch in batches:
            t_ref = None
            for label, (p, kfmt) in legs.items():
                best = None
                for rep in range(reps + 1):          # rep 0 warms jit
                    eng = _engine(p, cfg, batch=batch, kv_fmt=kfmt)
                    rep_out = replay(eng, synth_trace(tc_a))
                    if rep > 0:
                        best = (rep_out if best is None
                                or rep_out["wall_s"] < best["wall_s"]
                                else best)
                t_ms = round(best["wall_s"] * 1e3, 3)
                if label == "f32":
                    t_ref = t_ms
                row = {"section": "replay", "name": f"replay_{cfg.name}",
                       "config": f"fmt={label} b={batch}",
                       "t_new_ms": t_ms,
                       "tok_s": round(best["tok_s"], 1),
                       "req_s": round(best["req_s"], 2),
                       "occupancy": round(best["occupancy"], 3),
                       "steps": best["steps"],
                       "tokens": best["tokens"]}
                if label != "f32" and t_ref:
                    row["t_old_ms"] = t_ref
                    row["speedup"] = round(t_ref / t_ms, 3)
                results.append(row)
                print(f"replay {cfg.name:14s} b={batch} {label:7s} "
                      f"{t_ms:8.1f}ms  {best['tok_s']:7.1f} tok/s  "
                      f"{best['req_s']:5.2f} req/s  "
                      f"occ {best['occupancy']:.2f}", flush=True)


def bench_storage(results):
    """The >= 2x HBM claim, asserted on real pools and real params."""
    cfg, params = _params("qwen2-0.5b")
    for fmt, want in (("p16e1", 2.0), ("p8e2", 4.0)):
        pb = param_bytes(quantize_params(params, QuantConfig(fmt=fmt)))
        ratio = pb["q_f32_bytes"] / pb["word_bytes"]
        # total includes the int8 per-channel scales + unquantized
        # leaves (norms, biases), so it trails the pure wire ratio
        total = pb["f32_bytes"] / pb["bytes"]
        results.append({"section": "storage", "name": "weight_bytes",
                        "config": f"{cfg.name} {fmt}",
                        "word_bytes": pb["word_bytes"],
                        "f32_equiv_bytes": pb["q_f32_bytes"],
                        "saving_x": round(ratio, 3),
                        "total_saving_x": round(total, 3),
                        "identical": bool(ratio >= 2.0)})
        print(f"storage weights {fmt}: {ratio:.2f}x wire "
              f"({total:.2f}x total incl. scales)", flush=True)
        assert ratio >= 2.0 and abs(ratio - want) < 1e-9, (
            f"weight storage saving off: {ratio} != {want}")
    kb = _engine(params, cfg, batch=4, kv_fmt="p16e1").kv_bytes()
    kv_ratio = kb["f32_bytes"] / kb["bytes"]
    results.append({"section": "storage", "name": "kv_pool_bytes",
                    "config": f"{cfg.name} kv=p16e1 b=4",
                    "pool_bytes": kb["bytes"],
                    "f32_equiv_bytes": kb["f32_bytes"],
                    "saving_x": round(kv_ratio, 3),
                    "identical": bool(kv_ratio >= 2.0)})
    print(f"storage kv pool p16e1: {kv_ratio:.2f}x", flush=True)
    assert kv_ratio >= 2.0, f"KV pool saving below 2x: {kv_ratio}"


def bench_study(results, quick):
    archs = ("qwen2-0.5b",) if quick else ("qwen2-0.5b", "mamba2-780m")
    fmts = ("p16e1", "p8e2") if quick else ("p32e2", "p16e1", "p8e2")
    rows = quant_study(archs, fmts, seed=SEED)
    for r in rows:
        results.append({"section": "study", "name": f"quant_{r['arch']}",
                        "config": f"{r['fmt']} equil={r['equilibrated']}",
                        "rel_err": r["rel_err"], "kl": r["kl"],
                        "top1": r["top1"], "gz": r["gz"]})
    print(study_table(rows), flush=True)


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small trace / fewer legs (CI perf-smoke)")
    parser.add_argument("--out", default="BENCH_serve.json")
    args = parser.parse_args(argv)
    reps = 1 if args.quick else 2

    results = []
    gate_identity(results)          # MUST pass before any timing
    bench_replay(results, args.quick, reps)
    bench_storage(results)
    bench_study(results, args.quick)

    payload = {
        "meta": {
            "bench": "bench_serve", "quick": args.quick,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(results)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
