"""QR / least-squares benchmark: bit-identity and accuracy gated BEFORE
any timing (bench_decomp.py / bench_formats.py conventions).

Four sections, one BENCH_qr.json:

* ``identity`` — the schedule/dispatch contracts: blocked ``rgeqrf`` ==
                 Python-loop ``rgeqrf_loop`` (per backend), batched ==
                 single, the exact-accumulation backend family
                 (xla_quire == quire_exact) produces identical factor
                 words, and ``quire_gemv`` == ``quire_dot``.  A mismatch
                 aborts the benchmark.
* ``accuracy`` — the §5.1 sigma grid on the over-determined scenario:
                 ``rgels_ir``/``rgels_mp`` must sit on the true LS
                 optimum of the posit-held problem (digits_from_opt ~ 0)
                 with the narrow factorization costing ~0 digits
                 (digits_lost < 0.5) — the acceptance gate, re-asserted
                 here exactly as in tests/test_qr.py.
* ``timing``   — rgeqrf single-dispatch vs dispatch-per-block, and the
                 mixed-precision factor step (p16e1 vs p32e2 rgeqrf,
                 quire_exact trailing updates).  Interleaved best-of-N
                 (host drift cancels out of the ratio).
* ``ls``       — rgels vs rgels_ir wall-clock at the acceptance shape
                 (the price of the refined digits).

Schema: {meta, results: [{section, name, config, ...}]}; CI merges it
into BENCH_summary.json via benchmarks/merge_bench.py.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posit as P
from repro.core.formats import P16E1, P32E2
from repro.lapack import error_eval, qr
from repro.quire import quire_dot, quire_gemv

# the shared interleaved best-of-N estimator (see bench_decomp.py)
from bench_decomp import (_attach_metrics, _identical,  # noqa: E402
                          _time_pair)


def gate_identity(results, quick):
    """Assert every schedule contract BEFORE timing."""
    rng = np.random.default_rng(42)
    m, n, nb = (48, 32, 16) if quick else (72, 48, 16)
    ap = P.from_float64(jnp.asarray(rng.standard_normal((m, n))))

    checks = {}
    jit_out = qr.rgeqrf(ap, nb=nb)
    checks["blocked_vs_loop"] = _identical(jit_out, qr.rgeqrf_loop(ap, nb=nb))
    batched = qr.rgeqrf_batched(ap[None], nb=nb)
    checks["batched_vs_single"] = _identical(
        (batched[0][0], batched[1][0]), jit_out)
    checks["xla_quire_vs_quire_exact"] = _identical(
        jit_out, qr.rgeqrf(ap, nb=nb, gemm_backend="quire_exact"))
    xp = P.from_float64(jnp.asarray(rng.standard_normal(n)))
    checks["quire_gemv_vs_quire_dot"] = _identical(
        quire_gemv(ap, xp), quire_dot(ap, xp[None, :]))

    ok = all(checks.values())
    results.append({"section": "identity", "name": "qr_schedule_contracts",
                    "config": f"m={m} n={n} nb={nb} seed 42",
                    "identical": ok,
                    "mismatches": sorted(k for k, v in checks.items()
                                         if not v)})
    print(f"identity gates: {'OK' if ok else f'MISMATCH {checks}'}",
          flush=True)
    assert ok, f"qr schedule contract broken: {checks}"


def gate_accuracy(results, quick):
    """The acceptance grid: refined LS lands on the data-quantization
    floor, mixed precision loses ~0 digits — gated before timing."""
    m, n = (48, 32) if quick else (96, 64)
    sigmas = (1.0,) if quick else (1e-2, 1.0, 1e2)
    for sigma in sigmas:
        r = error_eval.least_squares_study(m, n, sigma, nb=16)
        results.append({
            "section": "accuracy", "name": "rgels_sigma_grid",
            "config": f"m={m} n={n} sigma={sigma:g}",
            "e_qr": r.e_qr, "e_ir": r.e_ir, "e_mp": r.e_mp,
            "e_opt": r.e_opt, "digits_vs_b32": round(r.digits, 3),
            "digits_lost": round(r.digits_lost, 3),
            "digits_from_opt": round(r.digits_from_opt, 3)})
        print(f"accuracy sigma={sigma:<8g} e_qr={r.e_qr:.2e} "
              f"e_ir={r.e_ir:.2e} e_mp={r.e_mp:.2e}  "
              f"from_opt {r.digits_from_opt:+.3f}  "
              f"lost {r.digits_lost:+.3f}", flush=True)
        assert r.digits_from_opt < 0.1, (
            f"refined LS did not reach the optimum floor: {r}")
        assert r.digits_lost < 0.5, (
            f"mp refinement failed to reach the IR floor: {r}")


def bench_timing(results, quick, reps):
    rng = np.random.default_rng(7)
    n = 96 if quick else 256
    m = n + n // 2
    nb = 16 if quick else 32
    a64 = rng.standard_normal((m, n))
    ap32 = P.from_float64(jnp.asarray(a64), P32E2)
    ap16 = P.from_float64(jnp.asarray(a64), P16E1)

    # single-dispatch vs dispatch-per-block (identity already gated)
    old = qr.rgeqrf_loop(ap32, nb=nb)
    new = qr.rgeqrf(ap32, nb=nb)
    assert _identical(old, new)
    t_old, t_new = _time_pair(lambda: qr.rgeqrf_loop(ap32, nb=nb),
                              lambda: qr.rgeqrf(ap32, nb=nb), reps)
    results.append(_attach_metrics({
        "section": "timing", "name": "rgeqrf_jit_vs_loop",
        "config": f"m={m} n={n} nb={nb}",
        "t_old_ms": round(t_old, 3), "t_new_ms": round(t_new, 3),
        "speedup": round(t_old / t_new, 3), "identical": True},
        lambda: qr.rgeqrf(ap32, nb=nb)))
    print(f"timing rgeqrf m={m} n={n}: loop {t_old:8.1f}ms  "
          f"jit {t_new:8.1f}ms  {t_old / t_new:5.2f}x", flush=True)

    # the mp factor step: p16e1 vs p32e2 rgeqrf, quire trailing updates.
    # Unlike LU (bench_formats: 1.2-1.3x), QR is PANEL-dominated in this
    # emulation — the chain-form panels and larft are format-independent
    # f64 work, so the 4-vs-16-limb quire win (the isolated trailing
    # update IS ~2x faster in p16e1) is a small fraction: expect ~1.0x
    # at dispatch-per-block granularity.  The single-dispatch row is
    # reported too because it currently shows an XLA artifact: fusing
    # the whole p16e1 program compiles ~2 min and emits SLOWER code than
    # the p32e2 program (DESIGN.md §9 cost note) — trajectory data worth
    # watching across jax upgrades, not an arithmetic claim.
    for name, f in (("rgeqrf_factor_fmt_loop", qr.rgeqrf_loop),
                    ("rgeqrf_factor_fmt_jit", qr.rgeqrf)):
        f32 = lambda: f(ap32, nb=nb, gemm_backend="quire_exact", fmt=P32E2)
        f16 = lambda: f(ap16, nb=nb, gemm_backend="quire_exact", fmt=P16E1)
        t32, t16 = _time_pair(f32, f16, reps)
        results.append({
            "section": "timing", "name": name,
            "config": f"m={m} n={n} nb={nb} quire_exact p16e1 vs p32e2",
            "t_old_ms": round(t32, 3), "t_new_ms": round(t16, 3),
            "speedup": round(t32 / t16, 3)})
        print(f"timing {name} m={m} n={n}: p32e2 {t32:8.1f}ms  "
              f"p16e1 {t16:8.1f}ms  {t32 / t16:5.2f}x", flush=True)


def bench_ls(results, quick, reps):
    rng = np.random.default_rng(11)
    m, n = (48, 32) if quick else (96, 64)
    a64 = rng.standard_normal((m, n))
    b64 = a64 @ np.full(n, 1.0 / np.sqrt(n))
    ap = P.from_float64(jnp.asarray(a64))
    bp = P.from_float64(jnp.asarray(b64))
    # jit both drivers so the comparison is steady-state compiled work
    # (the un-jitted refine loop would otherwise re-trace per call)
    plain_fn = jax.jit(lambda a, b: qr.rgels(a, b, nb=16)[0])
    ir_fn = jax.jit(lambda a, b: qr.rgels_ir(a, b, iters=3, nb=16)[0])
    plain = lambda: plain_fn(ap, bp)
    refined = lambda: ir_fn(ap, bp)
    t_plain, t_ir = _time_pair(plain, refined, max(2, reps // 2))
    results.append({
        "section": "ls", "name": "rgels_vs_rgels_ir",
        "config": f"m={m} n={n} iters=3",
        "t_old_ms": round(t_plain, 3), "t_new_ms": round(t_ir, 3),
        "speedup": round(t_plain / t_ir, 3)})
    print(f"ls rgels m={m} n={n}: plain {t_plain:8.1f}ms  "
          f"ir {t_ir:8.1f}ms  (refined digits cost "
          f"{t_ir / t_plain:.2f}x)", flush=True)


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small sizes / fewer reps (CI perf-smoke)")
    parser.add_argument("--out", default="BENCH_qr.json")
    args = parser.parse_args(argv)
    reps = 3 if args.quick else 6

    results = []
    gate_identity(results, args.quick)      # MUST pass before any timing
    gate_accuracy(results, args.quick)      # MUST pass before any timing
    bench_timing(results, args.quick, reps)
    bench_ls(results, args.quick, reps)

    payload = {
        "meta": {
            "bench": "bench_qr", "quick": args.quick,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(results)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
