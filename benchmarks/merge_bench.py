"""Merge BENCH_*.json artifacts into one BENCH_summary.json + a markdown
trajectory table.

    python benchmarks/merge_bench.py BENCH_*.json --out BENCH_summary.json \
        [--markdown]

``--markdown`` prints a GitHub-flavoured table to stdout; the CI
perf-smoke job appends it to ``$GITHUB_STEP_SUMMARY`` so per-PR perf
trajectory is visible in the run page without downloading artifacts.

Tolerant of the benches' differing row schemas: timing rows surface
(t_old_ms | t_single_ms) / (t_new_ms | t_dist_ms) / speedup, accuracy
rows surface their digits metric, and every row keeps its bit-identity
flag where one exists (the '!!' marker means a gate FAILED — the bench
itself asserts, so a failed gate normally never produces a file at all).
"""
from __future__ import annotations

import argparse
import json
import sys


def load(paths, skip=()):
    """Load bench payloads; a prior merge output (recognized by its
    ``merged_from`` key, or by matching ``skip`` paths) is ignored so
    re-running the documented BENCH_*.json glob doesn't nest the old
    summary inside the new one."""
    benches = {}
    for p in paths:
        if p in skip:
            continue
        with open(p) as f:
            payload = json.load(f)
        if "merged_from" in payload:
            continue
        name = payload.get("meta", {}).get("bench") or p
        benches[name] = payload
    return benches


def _fmt_ms(v):
    return f"{v:.1f}" if isinstance(v, (int, float)) else ""


def _row_cells(bench, r):
    name = r.get("name", "")
    config = str(r.get("config", ""))
    t_old = r.get("t_old_ms", r.get("t_single_ms"))
    t_new = r.get("t_new_ms", r.get("t_dist_ms"))
    speedup = r.get("speedup")
    if "digits_vs_b32" in r:
        metric = f"{r['digits_vs_b32']:+.2f} digits vs b32"
    elif "digits_lost" in r:
        metric = f"{r['digits_lost']:+.2f} digits lost"
    elif speedup is not None:
        metric = f"{speedup:.2f}x"
    else:
        metric = ""
    ident = r.get("identical")
    ok = "" if ident is None else ("ok" if ident else "!!")
    if r.get("devices") is not None:
        config = f"{config} x{r['devices']}dev"
    return [bench, name, config, _fmt_ms(t_old), _fmt_ms(t_new), metric, ok]


def markdown_table(benches) -> str:
    lines = ["## Bench trajectory", "",
             "| bench | row | config | old/ref ms | new ms | metric | gate |",
             "|---|---|---|---:|---:|---|---|"]
    for bench, payload in sorted(benches.items()):
        for r in payload.get("results", []):
            cells = _row_cells(bench, r)
            lines.append("| " + " | ".join(cells) + " |")
    metas = {b: p.get("meta", {}) for b, p in benches.items()}
    envs = {(m.get("python"), m.get("jax"), m.get("platform"))
            for m in metas.values()}
    env_strs = sorted(
        f"py {py or '?'} · jax {jx or '?'} · {plat or '?'}"
        for py, jx, plat in envs)
    lines += ["", *(f"_{e}_" for e in env_strs), ""]
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--out", default="BENCH_summary.json")
    ap.add_argument("--markdown", action="store_true",
                    help="print a markdown trajectory table to stdout")
    args = ap.parse_args(argv)

    benches = load(args.inputs, skip={args.out})
    summary = {
        "merged_from": sorted(p for p in args.inputs if p != args.out),
        "benches": benches,
    }
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(benches)} benches, "
          f"{sum(len(p.get('results', [])) for p in benches.values())} rows)",
          file=sys.stderr)
    if args.markdown:
        print(markdown_table(benches))


if __name__ == "__main__":
    main()
