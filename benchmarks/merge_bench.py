"""Merge BENCH_*.json artifacts into one BENCH_summary.json + a markdown
trajectory table, optionally diffed against committed baselines.

    python benchmarks/merge_bench.py BENCH_*.json --out BENCH_summary.json \
        [--markdown] [--baseline DIR]

``--markdown`` prints a GitHub-flavoured table to stdout; the CI
perf-smoke job appends it to ``$GITHUB_STEP_SUMMARY`` so per-PR perf
trajectory is visible in the run page without downloading artifacts.

``--baseline DIR`` compares each freshly produced timing row against the
committed BENCH_*.json in DIR and adds a **warn-only** ``vs base``
column.  Rows are matched on (bench, name, config, devices) and the
config string carries the problem size, so the baselines must be the
SAME granularity as the run: CI's perf-smoke (``--quick``) diffs
against the committed ``benchmarks/baselines/quick/`` set, while the
nightly full-size sweep stashes the repo-root BENCH_*.json (full runs)
out of the checkout before the benches overwrite the filenames.  The
column shows: the ratio baseline_ms / fresh_ms, so
> 1 means this run is faster than the committed numbers.  Rows slower
than ``_WARN_RATIO`` get a ``(slow)`` marker — a visibility aid, never a
failure: shared-runner drift is ±2x on these boxes, so the committed
baselines are trajectory data, not an SLA.  Rows without a baseline
counterpart (new benches, renamed configs) show ``-``.

Tolerant of the benches' differing row schemas: timing rows surface
(t_old_ms | t_single_ms) / (t_new_ms | t_dist_ms) / speedup, accuracy
rows surface their digits metric, and every row keeps its bit-identity
flag where one exists (the '!!' marker means a gate FAILED — the bench
itself asserts, so a failed gate normally never produces a file at all).
Rows carrying a ``metrics`` block (repro.obs ``bench_block()`` — an
un-timed observed re-run the benches attach post-timing) ride through
the summary verbatim, and the golden-zone occupancy gauge is surfaced
as ``gz`` in the metric column.  Serving rows (bench_serve) carry a
``tok_s`` field, surfaced as ``N tok/s`` alongside the latency ratio so
the trajectory table shows throughput too.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# below this fresh/baseline speed ratio a row is flagged "(slow)" in the
# markdown table (warn-only; see module docstring)
_WARN_RATIO = 0.5


def load(paths, skip=()):
    """Load bench payloads; a prior merge output (recognized by its
    ``merged_from`` key, or by matching ``skip`` paths) is ignored so
    re-running the documented BENCH_*.json glob doesn't nest the old
    summary inside the new one."""
    benches = {}
    for p in paths:
        if p in skip:
            continue
        with open(p) as f:
            payload = json.load(f)
        if "merged_from" in payload:
            continue
        name = payload.get("meta", {}).get("bench") or p
        benches[name] = payload
    return benches


def load_baseline(dir_):
    """Load the stashed committed BENCH_*.json files from ``dir_``.
    Tolerant per file: a truncated or non-JSON baseline is skipped with a
    warning (its rows just show no delta), never a crash — a bad
    committed artifact must not fail every future perf-smoke run."""
    benches = {}
    for p in sorted(glob.glob(os.path.join(dir_, "BENCH_*.json"))):
        try:
            benches.update(load([p]))
        except (json.JSONDecodeError, OSError) as e:
            print(f"merge_bench: skipping unreadable baseline {p}: {e}",
                  file=sys.stderr)
    return benches


def _fmt_ms(v):
    return f"{v:.1f}" if isinstance(v, (int, float)) else ""


def _row_key(r):
    return (r.get("name", ""), str(r.get("config", "")), r.get("devices"))


def _row_time(r):
    t = r.get("t_new_ms", r.get("t_dist_ms"))
    return t if isinstance(t, (int, float)) else None


def baseline_deltas(benches, baseline):
    """{(bench, row_key): ratio | None} for EVERY fresh timing row, with
    ratio = baseline_ms / fresh_ms when a matching row (same bench, name,
    config, devices) exists in the baseline set and None when it doesn't
    (> 1 means faster now).  A brand-new bench — BENCH_ft.json on its
    first run, before a baseline is committed — therefore still surfaces
    all its rows in the summary's baseline_diff, just with a null delta,
    instead of silently vanishing from the diff."""
    deltas = {}
    for bench, payload in benches.items():
        base_rows = {_row_key(r): r for r in
                     baseline.get(bench, {}).get("results", [])}
        for r in payload.get("results", []):
            t_new = _row_time(r)
            if not t_new:
                continue                       # accuracy row: no timing
            base = base_rows.get(_row_key(r))
            t_base = _row_time(base) if base else None
            deltas[(bench, _row_key(r))] = (t_base / t_new) if t_base \
                else None
    return deltas


def _row_cells(bench, r, deltas=None):
    name = r.get("name", "")
    config = str(r.get("config", ""))
    t_old = r.get("t_old_ms", r.get("t_single_ms"))
    t_new = r.get("t_new_ms", r.get("t_dist_ms"))
    speedup = r.get("speedup")
    if "digits_vs_b32" in r:
        metric = f"{r['digits_vs_b32']:+.2f} digits vs b32"
    elif "digits_lost" in r:
        metric = f"{r['digits_lost']:+.2f} digits lost"
    elif speedup is not None:
        metric = f"{speedup:.2f}x"
    else:
        metric = ""
    if r.get("tok_s") is not None:
        ts = f"{r['tok_s']:.0f} tok/s"
        metric = f"{metric}, {ts}" if metric else ts
    gauges = (r.get("metrics") or {}).get("gauges", {})
    gz = next((gauges[k] for k in sorted(gauges)
               if k.endswith(".golden_zone")), None)
    if gz is not None:
        metric = f"{metric}, gz {gz:.2f}" if metric else f"gz {gz:.2f}"
    ident = r.get("identical")
    ok = "" if ident is None else ("ok" if ident else "!!")
    if r.get("devices") is not None:
        config = f"{config} x{r['devices']}dev"
    cells = [bench, name, config, _fmt_ms(t_old), _fmt_ms(t_new), metric, ok]
    if deltas is not None:
        ratio = deltas.get((bench, _row_key(r)))
        if ratio is None:
            cells.append("-")
        else:
            cells.append(f"{ratio:.2f}x"
                         + (" (slow)" if ratio < _WARN_RATIO else ""))
    return cells


def markdown_table(benches, deltas=None) -> str:
    head = ["bench", "row", "config", "old/ref ms", "new ms", "metric",
            "gate"]
    align = ["---", "---", "---", "---:", "---:", "---", "---"]
    if deltas is not None:
        head.append("vs base")
        align.append("---")
    lines = ["## Bench trajectory", "",
             "| " + " | ".join(head) + " |",
             "|" + "|".join(align) + "|"]
    for bench, payload in sorted(benches.items()):
        for r in payload.get("results", []):
            cells = _row_cells(bench, r, deltas)
            lines.append("| " + " | ".join(cells) + " |")
    metas = {b: p.get("meta", {}) for b, p in benches.items()}
    envs = {(m.get("python"), m.get("jax"), m.get("platform"))
            for m in metas.values()}
    env_strs = sorted(
        f"py {py or '?'} · jax {jx or '?'} · {plat or '?'}"
        for py, jx, plat in envs)
    lines += ["", *(f"_{e}_" for e in env_strs)]
    if deltas is not None:
        lines.append("_vs base = committed-baseline ms / this-run ms "
                     "(warn-only; > 1 is faster than the committed "
                     "numbers)_")
    lines.append("")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--out", default="BENCH_summary.json")
    ap.add_argument("--markdown", action="store_true",
                    help="print a markdown trajectory table to stdout")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="directory holding the committed BENCH_*.json "
                         "baselines; adds a warn-only 'vs base' delta "
                         "column (never fails the run)")
    args = ap.parse_args(argv)

    benches = load(args.inputs, skip={args.out})
    deltas = None
    summary = {
        "merged_from": sorted(p for p in args.inputs if p != args.out),
        "benches": benches,
    }
    if args.baseline is not None:
        deltas = baseline_deltas(benches, load_baseline(args.baseline))
        summary["baseline_diff"] = [
            {"bench": b, "name": k[0], "config": k[1], "devices": k[2],
             "speed_vs_baseline": None if ratio is None
             else round(ratio, 3)}
            for (b, k), ratio in sorted(
                deltas.items(), key=lambda kv: (kv[0][0], kv[0][1][0],
                                                kv[0][1][1],
                                                kv[0][1][2] or 0))]
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(benches)} benches, "
          f"{sum(len(p.get('results', [])) for p in benches.values())} rows)",
          file=sys.stderr)
    if args.markdown:
        print(markdown_table(benches, deltas))


if __name__ == "__main__":
    main()
