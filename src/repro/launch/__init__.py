"""Distributed runtime: mesh construction, sharding rules, compressed
collectives, step builders, dry-run and training drivers."""
