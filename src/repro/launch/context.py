"""Trace-time distribution context.

Step builders set this before tracing a model function; layers that need
*manual* collectives (the EP MoE all_to_all dispatch) read it to decide
between the single-device path and the shard_map path.  It is static
configuration, not runtime state — everything it carries is hashable and
known before lowering.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Mesh
    dp: tuple[str, ...]            # data-parallel mesh axes for batch dims
    ep: str = "model"              # expert-parallel axis
    seq: Optional[str] = None      # sequence-sharding axis (activations)
    f32_partials: bool = False     # decode: f32 dot outputs (XLA CPU's
                                   # AllReducePromotion CHECK-fails on the
                                   # bf16 partial-product all-reduces that
                                   # replicated-activation decode produces)


_ctx: contextvars.ContextVar[Optional[DistContext]] = contextvars.ContextVar(
    "repro_dist_context", default=None)


def current() -> Optional[DistContext]:
    return _ctx.get()


@contextlib.contextmanager
def use(dist: Optional[DistContext]):
    tok = _ctx.set(dist)
    try:
        yield
    finally:
        _ctx.reset(tok)
