"""HLO text analysis: collective-byte accounting for the roofline."""
from __future__ import annotations

import re

# s64/u64 matter here: the quire limb planes the distributed schedules
# psum/reduce-scatter (dist/pblas.py) are int64 — before they were added
# those collectives silently counted as 0 bytes.
_SHAPE_RE = re.compile(r"(c128|c64|f64|f32|bf16|f16|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_BYTES = {"c128": 16, "c64": 8, "f64": 8, "f32": 4, "s64": 8, "u64": 8,
          "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "s8": 1, "u8": 1, "pred": 1}
_LINE_RE = re.compile(r".*= *((?:\([^)]*\))|(?:[a-z0-9\[\],{} ]*)) *"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes of every collective op in optimized HLO text."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line.strip())
        if not m:
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    return out
