"""JAX version compatibility shims.

``jax.shard_map`` (with ``axis_names`` / ``check_vma``) landed after the
pinned jax 0.4.x; on older versions the same primitive lives at
``jax.experimental.shard_map.shard_map`` with the (mesh-complement)
``auto`` parameter and ``check_rep`` instead.  ``shard_map`` below is the
one entry point every call site uses (launch/steps.py, models/common.py,
models/ffn.py), and importing this module also installs it as
``jax.shard_map`` when absent so version-agnostic snippets (and the
subprocess tests) run unchanged.
"""
from __future__ import annotations

import jax

try:
    _native = jax.shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return _native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=check_vma, **kw)

except AttributeError:
    from jax.experimental.shard_map import shard_map as _legacy

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        """New-style jax.shard_map API on legacy jax: ``axis_names`` lists
        the MANUAL axes; everything else in the mesh stays automatic
        (legacy expresses the complement via ``auto``)."""
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=check_vma, auto=auto)

    jax.shard_map = shard_map


try:
    axis_size = jax.lax.axis_size
except AttributeError:
    import jax._src.core as _core

    def axis_size(axis_name) -> int:
        """Static size of a manual mesh axis inside shard_map (legacy jax:
        ``core.axis_frame(name)`` returns the bound size directly)."""
        return _core.axis_frame(axis_name)

    jax.lax.axis_size = axis_size


# Device coordinate inside a manual mesh axis.  Stable across the jax
# versions we straddle; re-exported here so repro.dist (and any other
# shard_map consumer) takes every mesh-manual primitive — shard_map,
# axis_size, axis_index — from this one compat surface instead of
# mixing shimmed and raw jax.lax lookups.
axis_index = jax.lax.axis_index
