"""Logical-axis -> mesh-axis sharding rules.

Params carry logical axes ('mlp', 'heads', 'experts', 'vocab', 'embed',
None) from init; these rules turn them into NamedShardings:

* TP      — 'mlp'/'heads'/'experts'/'vocab' -> 'model' (Megatron column/row,
            expert parallelism for MoE, vocab-parallel embedding).
* FSDP    — additionally shard the largest unsharded dim of every big
            param over 'data' (required for llama3-405b-class memory).
* DP      — batch dims over ('pod','data'); multi-pod adds pure-DP 'pod'.
* SP      — prefill activations / decode KV caches shard sequence over
            'model' (GQA keeps KV small, so TP attention gives way to
            sequence sharding at long context — DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeCell
from repro.launch.mesh import dp_axes
from repro.models.common import ArchConfig, Axes, is_param

LOGICAL = {"mlp": "model", "heads": "model", "experts": "model",
           "vocab": "model", "embed": None}

# archs whose param+optimizer footprint forces FSDP over 'data'
FSDP_ARCHS = {"llama3-405b", "internvl2-26b", "moonshot-v1-16b-a3b",
              "gemma3-12b", "starcoder2-7b"}
_FSDP_MIN_SIZE = 1 << 22          # only shard params >= 4M elements


def _spec_for_axes(axes: Axes, shape, mesh, fsdp: bool) -> P:
    names: list[Optional[str]] = [LOGICAL.get(a) if a else None
                                  for a in axes]
    # stacked layer params carry an extra leading (n_layers/period) dim;
    # those positions never take a mesh axis (scan slices them)
    n_stack = len(shape) - len(names)
    while len(names) < len(shape):
        names.insert(0, None)
    # drop assignments that don't divide, and duplicate mesh axes after the
    # first occurrence (e.g. MoE (experts, d, mlp): EP wins, mlp replicates)
    seen: set[str] = set()
    for i, mx in enumerate(names):
        if mx is None:
            continue
        if shape[i] % mesh.shape[mx] != 0 or mx in seen:
            names[i] = None
        else:
            seen.add(mx)
    if fsdp and int(np.prod(shape)) >= _FSDP_MIN_SIZE:
        # shard the largest still-unsharded non-stack dim over the full DP
        # extent ('pod' included on the multi-pod mesh)
        fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes]))
        cand = [i for i, mx in enumerate(names) if mx is None
                and i >= n_stack and shape[i] % fsdp_size == 0]
        if cand:
            big = max(cand, key=lambda i: shape[i])
            names[big] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*names)


def param_shardings(abstract_params, cfg: ArchConfig, mesh: Mesh):
    """Map the abstract param tree (with Axes nodes) to NamedShardings."""
    fsdp = cfg.name in FSDP_ARCHS

    def walk(tree):
        if isinstance(tree, Axes):
            return tree
        if is_param(tree):
            spec = _spec_for_axes(tree["axes"], tree["w"].shape, mesh, fsdp)
            return {"w": NamedSharding(mesh, spec), "axes": tree["axes"]}
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v) for v in tree)
        return tree
    return walk(abstract_params)


def opt_shardings(abstract_opt, param_sh, mesh: Mesh):
    """Optimizer moments inherit their param's sharding (compressed int16
    moments share the same layout); step counter replicated."""
    def walk(opt, ps):
        if isinstance(opt, Axes):
            return opt
        if isinstance(opt, dict) and set(opt) == {"m", "v"}:
            # ps is the param's NamedSharding (parent key was "w")
            sh = ps if isinstance(ps, NamedSharding) \
                else NamedSharding(mesh, P())
            return {"m": sh, "v": sh}
        if isinstance(opt, dict):
            return {k: walk(v, ps[k] if isinstance(ps, dict) and k in ps
                            else ps) for k, v in opt.items()}
        if isinstance(opt, (list, tuple)):
            return type(opt)(walk(v, ps[i]) for i, v in enumerate(opt))
        return NamedSharding(mesh, P())

    return {"moments": walk(abstract_opt["moments"], param_sh),
            "step": NamedSharding(mesh, P())}


def _dp_for(batch: int, mesh) -> Optional[tuple[str, ...]]:
    dp = dp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in dp]))
    if dp and batch % size == 0:
        return dp
    if "data" in dp and batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def dist_for(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
             seq_shard: bool = True):
    """DistContext matching batch_shardings' choices for this cell."""
    from repro.launch.context import DistContext
    dp = _dp_for(cell.global_batch, mesh) or ()
    seq = "model" if (seq_shard and cell.seq_len % mesh.shape["model"] == 0
                      and cell.kind in ("train", "prefill")) else None
    return DistContext(mesh=mesh, dp=tuple(dp), ep="model", seq=seq,
                       f32_partials=(cell.kind == "decode"))


def batch_shardings(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
                    seq_shard: bool = True):
    """Shardings for the input batch of a train/prefill step."""
    dp = _dp_for(cell.global_batch, mesh)
    sq = "model" if (seq_shard and cell.seq_len % mesh.shape["model"] == 0
                     and cell.kind in ("train", "prefill")) else None
    tok = NamedSharding(mesh, P(dp, sq))
    out = {"tokens": tok, "targets": tok}
    if cfg.family == "encdec":
        out["frames"] = NamedSharding(mesh, P(dp, None, None))
    if cfg.family == "vlm":
        out["vis"] = NamedSharding(mesh, P(dp, None, None))
    return out


def cache_shardings(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh,
                    abstract_cache):
    """Decode-cache shardings: batch over DP axes, KV sequence over
    'model' (SP), SSM state heads over 'model'."""
    dp = _dp_for(cell.global_batch, mesh)

    def _stacked(spec_tail, ndim):
        """Caches are stacked with a leading layers/period dim."""
        spec = list(spec_tail)
        while len(spec) < ndim:
            spec.insert(0, None)
        return P(*spec)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, path) for v in tree)
        nd = len(tree.shape)
        if path[-1:] in (("k",), ("v",)):               # (..., B, S, Hkv, Dh)
            msz = mesh.shape["model"]
            if dp is not None and tree.shape[-3] % msz == 0:
                # batched decode: sequence-sharded KV (SP)
                tail = (dp, "model", None, None)
            elif tree.shape[-2] % msz == 0:
                # batch-1 long-context: head-sharded KV (GSPMD crashes on
                # dp-less + S-sharded ring updates; heads/Dh shard instead)
                tail = (dp, None, "model", None)
            elif tree.shape[-1] % msz == 0:
                tail = (dp, None, None, "model")
            else:
                tail = (dp, None, None, None)
            return NamedSharding(mesh, _stacked(tail, nd))
        if path and path[-1] == "conv":                 # (..., B, k-1, C)
            c_ok = tree.shape[-1] % mesh.shape["model"] == 0
            tail = (dp, None, "model" if c_ok else None)
            return NamedSharding(mesh, _stacked(tail, nd))
        if path and path[-1] == "h":                    # (..., B, H, N, P)
            h_ok = tree.shape[-3] % mesh.shape["model"] == 0
            tail = (dp, "model" if h_ok else None, None, None)
            return NamedSharding(mesh, _stacked(tail, nd))
        if path and path[-1] == "cross_kv":             # (NL, B, Se, H, Dh)
            return NamedSharding(mesh, _stacked((dp, None, None, None), nd))
        return NamedSharding(mesh, P())
    return walk(abstract_cache)
