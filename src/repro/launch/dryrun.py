import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first init) — spec: MULTI-POD DRY-RUN item 0.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this produces:
  * memory_analysis()  — proves the program fits per-device HBM
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms
  * collective_bytes   — parsed from the optimized HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)
and appends a JSON record under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --cell train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both      # the full matrix
"""
import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, applicable_cells, cell_by_name,
                           get_config)
from repro.data.pipeline import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch import sharding as shd
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step, make_train_step_compressed)
from repro.models import init_cache, init_params
from repro.optim import adamw_init

from repro.launch.hlo_analysis import collective_bytes


def abstract_tree(f, *args, **kw):
    return jax.eval_shape(f, *args, **kw)


def _attach(tree, sh_tree):
    """ShapeDtypeStruct tree + sharding tree -> SDS-with-sharding tree."""
    def one(x, s):
        if hasattr(x, "shape") and hasattr(s, "spec"):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)
        return x
    return jax.tree.map(one, tree, sh_tree,
                        is_leaf=lambda x: hasattr(x, "spec"))


def build_cell(arch: str, cell_name: str, mesh, *, policy=None,
               compressed: bool = False):
    """Returns (jitted_fn, abstract_args) ready to .lower(*args)."""
    cfg = get_config(arch, policy)
    cell = cell_by_name(cell_name)
    key = jax.random.PRNGKey(0)
    dist = shd.dist_for(cfg, cell, mesh)

    params_abs = abstract_tree(functools.partial(init_params, cfg=cfg), key)
    param_sh = shd.param_shardings(params_abs, cfg, mesh)
    params_in = _attach(params_abs, param_sh)

    if cell.kind == "train":
        compress_m = cfg.get_policy().opt_compression is not None
        opt_abs = abstract_tree(functools.partial(
            adamw_init, compress_moments=compress_m), params_abs)
        opt_sh = shd.opt_shardings(opt_abs, param_sh, mesh)
        opt_in = _attach(opt_abs, opt_sh)
        batch_abs = input_specs(cfg, cell)
        batch_sh = shd.batch_shardings(cfg, cell, mesh)
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                            sharding=batch_sh[k])
                    for k, v in batch_abs.items()}
        if compressed:
            step = make_train_step_compressed(cfg, mesh, dist=dist)
        else:
            step = make_train_step(cfg, dist=dist)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (params_in, opt_in, batch_in), cfg

    if cell.kind == "prefill":
        batch_abs = input_specs(cfg, cell)
        batch_sh = shd.batch_shardings(cfg, cell, mesh)
        batch_in = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                            sharding=batch_sh[k])
                    for k, v in batch_abs.items()}
        fn = jax.jit(make_prefill_step(cfg, dist=dist))
        return fn, (params_in, batch_in), cfg

    # decode
    cache_abs = abstract_tree(functools.partial(
        init_cache, cfg, cell.global_batch, cell.seq_len))
    if cfg.family == "encdec":
        # stacked encoder cross-KV is part of the serve state
        nl = cfg.n_layers
        cache_abs = dict(cache_abs)
        kv = jax.ShapeDtypeStruct(
            (nl, cell.global_batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head),
            jnp.bfloat16)
        cache_abs["cross_kv"] = (kv, kv)
    cache_sh = shd.cache_shardings(cfg, cell, mesh, cache_abs)
    cache_in = _attach(cache_abs, cache_sh)
    io = input_specs(cfg, cell)
    dp = shd._dp_for(cell.global_batch, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    tok_in = jax.ShapeDtypeStruct(io["tokens"].shape, jnp.int32,
                                  sharding=NamedSharding(mesh, P(dp, None)))
    pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P()))
    fn = jax.jit(make_serve_step(cfg, dist=dist), donate_argnums=(1,))
    return fn, (params_in, cache_in, tok_in, pos_in), cfg


def run_cell(arch: str, cell_name: str, mesh_kind: str, *, policy=None,
             compressed: bool = False, outdir: str = "experiments/dryrun",
             verbose: bool = True):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    with mesh:
        fn, args, cfg = build_cell(arch, cell_name, mesh, policy=policy,
                                   compressed=compressed)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "cell": cell_name, "mesh": mesh_kind,
        "policy": policy or "default", "compressed": compressed,
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_size_bytes": getattr(
            mem, "generated_code_size_in_bytes", None),
    }
    if verbose:
        print(f"[dryrun] {arch} x {cell_name} x {mesh_kind}"
              f"{' +compressed' if compressed else ''}")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={rec['argument_size_bytes']}"
              f" temp={rec['temp_size_bytes']} out={rec['output_size_bytes']}")
        print(f"  cost_analysis: flops={rec['flops']:.3e}"
              f" bytes={rec['bytes_accessed']:.3e}")
        print(f"  collectives: {coll}")
    os.makedirs(outdir, exist_ok=True)
    tag = f"{arch}_{cell_name}_{mesh_kind}" + ("_comp" if compressed else "")
    if policy:
        tag += f"_{policy}"
    with open(os.path.join(outdir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--policy", default=None)
    ap.add_argument("--compressed", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        cells = ([cell_by_name(args.cell)] if args.cell
                 else applicable_cells(cfg))
        for cell in cells:
            for mk in meshes:
                try:
                    run_cell(arch, cell.name, mk, policy=args.policy,
                             compressed=args.compressed, outdir=args.outdir)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, cell.name, mk, repr(e)[:300]))
                    print(f"[FAIL] {arch} x {cell.name} x {mk}: "
                          f"{repr(e)[:300]}")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        sys.exit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
