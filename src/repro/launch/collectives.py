"""Posit-compressed gradient collectives (beyond-paper distributed trick).

``compressed_psum`` implements reduce-scatter + all-gather with both wire
phases carried as Posit(16,1) words after golden-zone re-centering: the
gradient tensor is scaled so its typical magnitude sits where p16e1 has
its 12-bit fraction (the paper's §5.1 scaling recommendation applied to
collectives).  Bytes on the wire: 2 x n x 2B vs f32 ring all-reduce's
2 x n x 4B — a 2x reduction on the cross-pod (slowest) links.

Used inside shard_map with manual axes ('pod', and optionally 'data');
the 'model' axis stays automatic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.compat import axis_size  # also installs jax.shard_map shim
from repro.core.policy import decode_tensor, encode_tensor

_GRAD_SCALE = 2.0 ** 8     # golden-zone re-centering for layer-norm'd grads


def compressed_psum(x: jax.Array, axis_name: str,
                    scale: float = _GRAD_SCALE) -> jax.Array:
    """Sum ``x`` across ``axis_name`` with p16e1-compressed wire traffic.

    reduce-scatter phase: all_to_all of encoded chunks, decode, local sum;
    all-gather phase: encoded own-chunk broadcast.  Mathematically the
    standard two-phase all-reduce; wire dtype int16.
    """
    p = axis_size(axis_name)
    orig_shape = x.shape
    orig_dtype = x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % p
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(p, -1)

    enc = encode_tensor(chunks * jnp.float32(scale), "p16e1")      # int16
    recv = jax.lax.all_to_all(enc, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)                          # (p, m)
    own = jnp.sum(decode_tensor(recv, "p16e1"), axis=0)             # (m,)
    enc2 = encode_tensor(own, "p16e1")
    full = jax.lax.all_gather(enc2, axis_name, tiled=False)         # (p, m)
    out = decode_tensor(full, "p16e1") * jnp.float32(1.0 / scale)
    out = out.reshape(-1)[:n].reshape(orig_shape)
    return out.astype(orig_dtype)


def compressed_psum_tree(tree, axis_name: str, min_size: int = 1 << 12):
    """Apply compressed_psum to large leaves; small leaves use plain psum
    (collective-launch overhead dominates below ~4K elements)."""
    def one(g):
        if g.size >= min_size and jnp.issubdtype(g.dtype, jnp.floating):
            return compressed_psum(g, axis_name)
        return jax.lax.psum(g, axis_name)
    return jax.tree.map(one, tree)


def limb_psum(limbs: jax.Array, nar: jax.Array, axis_name: str):
    """Cross-device quire reduction in LIMB space (repro.dist contract).

    ``limbs`` (..., L) int64 redundant radix-2^32 limbs from disjoint
    K slabs; ``nar`` (...) bool poison flags.  Integer limb adds are
    associative, so psum-ing the planes and rounding ONCE afterwards is
    bit-identical to accumulating the whole K range on one device — the
    reduction wire-format is exact by construction, unlike any float
    partial-sum scheme.  Headroom is unchanged: the psum reassociates the
    same K-term sum, so the K * 2^32 per-limb bound (DESIGN.md §6.1)
    already covers the merged state.  NaR ORs across devices (any NaR
    input poisons the fused op, per the standard).
    """
    limbs = jax.lax.psum(limbs, axis_name)
    nar = jax.lax.psum(jnp.asarray(nar, jnp.int32), axis_name) > 0
    return limbs, nar
