"""End-to-end training driver (deliverable b: the runnable e2e example).

Runs real optimization steps on CPU with a reduced config (or any assigned
arch config at your own risk), with checkpoint/restart fault tolerance:

  python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 200
  # kill it at any point, then resume:
  python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 200 \\
      --ckpt-dir /tmp/ckpt   # resumes from the latest step automatically

The data pipeline is a pure function of (seed, step), so a restarted run
reproduces the exact same batch stream — training is bitwise-continuable
after a failure (tested in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.configs.shapes import ShapeCell
from repro.data.pipeline import make_batch
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import adamw_init


def run(arch: str, smoke: bool = True, steps: int = 50, batch: int = 4,
        seq: int = 64, ckpt_dir: str | None = None, ckpt_every: int = 20,
        lr: float = 1e-3, seed: int = 0, log_every: int = 10,
        policy: str | None = None):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if policy:
        import dataclasses
        cfg = dataclasses.replace(cfg, policy=policy)
    cell = ShapeCell("e2e", "train", seq, batch)
    compress = cfg.get_policy().opt_compression is not None

    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    opt = adamw_init(params, compress_moments=compress)
    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt), start, extra = restore_checkpoint(
            ckpt_dir, (params, opt))
        print(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, remat=False, lr=lr),
                      donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch_data = make_batch(cfg, cell, step, seed=seed,
                                batch_override=batch)
        params, opt, metrics = step_fn(params, opt, batch_data)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, (params, opt),
                            extra={"arch": arch, "loss": losses[-1]})
    return params, opt, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--policy", default=None)
    args = ap.parse_args(argv)
    _, _, losses = run(args.arch, smoke=args.smoke, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       lr=args.lr, policy=args.policy)
    print(f"[train] first loss {losses[0]:.4f} -> last loss "
          f"{losses[-1]:.4f}")


if __name__ == "__main__":
    main()
