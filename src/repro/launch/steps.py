"""Step builders: train_step (fwd+bwd+AdamW), prefill_step, serve_step.

``make_train_step``    — jit auto-parallel (XLA inserts all collectives).
``make_train_step_compressed`` — shard_map with manual DP axes: gradients
are synced by the posit16-compressed two-phase all-reduce from
launch/collectives.py; the 'model' axis stays automatic.  This is the
paper-aligned distributed-optimization variant (§Perf compares both).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import context as dist_ctx
from repro.launch.collectives import compressed_psum_tree
from repro.launch.compat import axis_size, shard_map
from repro.launch.mesh import dp_axes
from repro.models.common import ArchConfig
from repro.models.lm import forward_prefill, forward_train, serve_step
from repro.optim import adamw_update


def _cast_params(params, dtype):
    """One f32->compute-dtype cast per step on the SHARDED masters, so FSDP
    all-gathers move compute-dtype bytes (gather-then-convert would move
    f32; observed as 3.25 GiB f32 weight gathers on llama3-405b)."""
    def cast(w):
        if hasattr(w, "dtype") and w.dtype == jnp.float32:
            return w.astype(dtype)
        return w
    return jax.tree.map(cast, params)


def make_train_step(cfg: ArchConfig, *, remat: bool = True, lr: float = 3e-4,
                    dist=None):
    compress_moments = cfg.get_policy().opt_compression is not None
    compute_dtype = jnp.dtype(cfg.get_policy().compute_dtype)

    def train_step(params, opt_state, batch):
        with dist_ctx.use(dist):
            def loss_fn(pc):
                loss, metrics = forward_train(pc, batch, cfg, remat=remat)
                return loss, metrics
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(_cast_params(params, compute_dtype))
        params2, opt2, gnorm = adamw_update(
            params, opt_state, grads, lr=lr,
            compress_moments=compress_moments)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params2, opt2, metrics

    return train_step


def make_train_step_compressed(cfg: ArchConfig, mesh, *, remat: bool = True,
                               lr: float = 3e-4, dist=None):
    """Manual-DP variant: per-DP-shard fwd/bwd, then posit16-compressed
    gradient all-reduce across the DP axes ('pod' first — the slow links).
    """
    compress_moments = cfg.get_policy().opt_compression is not None
    dp = dp_axes(mesh)

    def per_shard(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = forward_train(p, batch, cfg, remat=remat)
            return loss, metrics
        with dist_ctx.use(None):   # inside manual DP: MoE uses local path
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
        # average across DP shards with compressed wire traffic
        for ax in dp:
            grads = compressed_psum_tree(grads, ax)
        dp_size = 1
        for ax in dp:
            dp_size *= axis_size(ax)
        grads = jax.tree.map(lambda g: g / dp_size, grads)
        params2, opt2, gnorm = adamw_update(
            params, opt_state, grads, lr=lr,
            compress_moments=compress_moments)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp), metrics)
        return params2, opt2, metrics

    def train_step(params, opt_state, batch):
        # params/opt replicated over DP (model-axis sharding stays auto);
        # batch split over DP on its leading dim.
        f = shard_map(
            per_shard, mesh=mesh,
            in_specs=(P(), P(), P(dp)),
            out_specs=(P(), P(), P()),
            axis_names=set(dp),
            check_vma=False)
        return f(params, opt_state, batch)

    return train_step


def make_prefill_step(cfg: ArchConfig, dist=None):
    def prefill_step(params, batch):
        with dist_ctx.use(dist):
            return forward_prefill(params, batch, cfg)
    return prefill_step


def make_serve_step(cfg: ArchConfig, dist=None):
    def step(params, cache, tokens, pos):
        with dist_ctx.use(dist):
            return serve_step(params, cache, tokens, pos, cfg)
    return step
