"""Production mesh construction (spec: MULTI-POD DRY-RUN item 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod),
multi-pod: 2x16x16 = 512 chips with a leading "pod" data-parallel axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(model: int = 1):
    """1-device mesh for CPU smoke paths (same axis names)."""
    return jax.make_mesh((1, model), ("data", "model"))


def make_grid_mesh(p: int = 1, q: int = 1):
    """P x Q ("row", "col") process grid for the distributed linear
    algebra subsystem (repro.dist) — ScaLAPACK's 2D grid in mesh form.
    Runs on any device set: TPU slices, or CPU host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the hermetic
    tier-1 path)."""
    return jax.make_mesh((p, q), ("row", "col"))


def dp_axes(mesh) -> tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
