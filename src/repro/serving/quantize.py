"""Posit weight quantization for LLM inference (weights-only PTQ).

The paper's accuracy story is that narrow posits keep more significand
than narrow IEEE floats *inside the golden zone*; the modern workload
where narrow weights directly buy throughput is LLM serving (bits per
weight = HBM bytes = bandwidth = tokens/sec).  This module stores model
weights as posit words in the format's **wire dtype** (int16 for p16e1,
int8 for p8e2 — a 2x/4x HBM saving over f32) with a **per-channel
power-of-two equilibration** reusing the PR-4 golden-zone machinery
(``lapack.refine.pow2_scale``, here per output channel): dividing each
channel by 2^floor(log2(max|w|)) puts its magnitudes in (1/2, 2] — the
top of every format's golden zone, where the posit keeps its maximal
fraction width — and the scale is folded back into the matmul output
exactly (power-of-two scaling is exact in f32).

Quantized leaves travel inside the ordinary param pytree: a leaf
``{"qw", "sexp", "qmeta", "axes"}`` replaces the f32 ``{"w", "axes"}``
leaf, and ``models.common.leaf``/``linear`` detect it — so the
quantized ``forward_prefill``/``serve_step`` run through EVERY
``ArchConfig`` family with no per-family code.  Two matmul paths:

* ``backend="xla"``  — decode words -> f32 inside the jit (the
  dequantize-on-load fallback; storage is narrow, compute is the
  baseline dot).  Weights-only semantics: activations untouched.
* ``backend="pallas"`` — encode activations to the same format and feed
  both word operands to the PR-2 fused-encode Pallas GEMM
  (``kernels.posit_gemm``), which decodes in-VMEM and accumulates in
  f32 — the native posit execution of the serving matmul.  Full-posit
  semantics (activations are rounded to the lattice too).

NaR / saturation hygiene: ``from_float32_bits`` maps NaN/Inf weights to
NaR and saturates at +-maxpos.  After per-channel equilibration
max|w/s| <= 2, far inside every format's range, so saturation can only
fire with ``per_channel=False``; ``quantize_params`` refuses NaR
(``core.posit.is_nar``) unless ``allow_nar=True``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posit
from repro.core.formats import get_format
from repro.models.common import Axes, is_param


class QMeta(tuple):
    """Static (fmt_name, backend) annotation — registered with no JAX
    leaves (like ``Axes``) so quantized leaves jit/tree-map cleanly."""


jax.tree_util.register_pytree_node(
    QMeta, lambda a: ((), tuple(a)), lambda aux, _: QMeta(aux))


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How to quantize: storage format, equilibration, matmul backend."""
    fmt: str = "p16e1"
    per_channel: bool = True      # pow2 equilibration per output channel
    backend: str = "xla"          # "xla" decode fallback | "pallas" GEMM
    min_ndim: int = 2             # only quantize leaves with ndim >= this
    block: int = 32               # pallas tile (pad-to multiple)


def is_qleaf(x) -> bool:
    return isinstance(x, dict) and "qw" in x


def channel_scale_exp(w) -> jax.Array:
    """Per-output-channel power-of-two exponent e with 2^e = the
    ``refine.pow2_scale`` equilibration of that channel: max|w| / 2^e in
    [1, 2).  Reduces over axis -2 (the contraction axis) ONLY, so a
    stacked (n_layers, d_in, d_out) scan leaf gets independent
    per-layer-per-channel scales that ``lax.scan`` slices alongside the
    words.  int8 (|e| <= 127 covers every f32 magnitude); all-zero
    channels get e = 0."""
    w = jnp.asarray(w, jnp.float32)
    mx = jnp.max(jnp.abs(jnp.where(jnp.isnan(w), 0.0, w)), axis=-2)
    safe = jnp.where(mx > 0, mx, 1.0)
    return jnp.clip(jnp.floor(jnp.log2(safe)), -126, 126).astype(jnp.int8)


def quantize_leaf(pl: dict, qc: QuantConfig) -> dict:
    """f32 param leaf {"w", "axes"} -> quantized leaf
    {"qw" (wire words), "sexp" (int8 pow2 exponents), "qmeta", "axes"}."""
    fmt = get_format(qc.fmt)
    w = jnp.asarray(pl["w"], jnp.float32)
    if qc.per_channel:
        sexp = channel_scale_exp(w)
    else:
        sexp = jnp.zeros(w.shape[:-2] + (w.shape[-1],), jnp.int8)
    scaled = w * jnp.exp2(-sexp.astype(jnp.float32))[..., None, :]
    words = posit.from_float32_bits(scaled, fmt)
    return {"qw": words.astype(fmt.wire_dtype), "sexp": sexp,
            "qmeta": QMeta((qc.fmt, qc.backend)),
            "axes": pl.get("axes", Axes((None,) * w.ndim))}


def dequant_leaf(ql: dict, dtype=jnp.float32) -> jax.Array:
    """Decode a quantized leaf back to values: decode(words) * 2^sexp.
    Exact inverse of the encode's rounding (the pow2 scale is applied in
    f32, which is exact for every posit value of <= 24-bit fraction;
    p32e2 values round once to f32, the same rounding the baseline f32
    stack already carries)."""
    fmt_name, _ = ql["qmeta"]
    fmt = get_format(fmt_name)
    w = posit.to_float32_bits(jnp.asarray(ql["qw"], jnp.int32), fmt)
    s = jnp.exp2(ql["sexp"].astype(jnp.float32))[..., None, :]
    return (w * s).astype(dtype)


# Param-leaf parent keys that are matmul/conv WEIGHTS (consumed along
# their -2 contraction axis).  Stacked 1-D leaves (biases, norm scales,
# SSM A_log/D/dt_bias) also look 2-D under the layer-scan stacking, so
# an ndim test alone would mis-scale them — the name is the contract.
QUANT_LEAF_KEYS = frozenset(
    {"w", "table", "conv_w", "w_gate", "w_up", "w_down"})


def _default_predicate(pl, qc: QuantConfig, name: str) -> bool:
    return name in QUANT_LEAF_KEYS and jnp.ndim(pl["w"]) >= qc.min_ndim


def quantize_params(params, qc: QuantConfig | None = None, *,
                    predicate=None, allow_nar: bool = False):
    """Quantize every matching param leaf of a model pytree (matmul
    weights, embedding tables and conv kernels by default — see
    ``QUANT_LEAF_KEYS``; biases/norms stay f32, they are O(d) of the
    O(d^2) total).  ``predicate(leaf, qc, name)`` overrides.

    Raises on NaR words (NaN/Inf weights) unless ``allow_nar``."""
    qc = qc or QuantConfig()
    pred = predicate or _default_predicate
    fmt = get_format(qc.fmt)
    nar_leaves: list[str] = []

    def visit(tree, path, name):
        if is_param(tree):
            if not pred(tree, qc, name):
                return tree
            ql = quantize_leaf(tree, qc)
            if int(jnp.sum(posit.is_nar(
                    jnp.asarray(ql["qw"], jnp.int32), fmt))):
                nar_leaves.append(path)
            return ql
        if isinstance(tree, dict):
            return {k: visit(v, f"{path}/{k}", k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(visit(v, f"{path}/{i}", name)
                              for i, v in enumerate(tree))
        return tree

    out = visit(params, "", "")
    if nar_leaves and not allow_nar:
        raise ValueError(
            f"NaR posit words (NaN/Inf weights) in {nar_leaves}; clean the "
            "checkpoint or pass allow_nar=True")
    return out


def dequantize_params(params, dtype=jnp.float32):
    """Inverse of ``quantize_params`` (up to the one encode rounding)."""
    def visit(tree):
        if is_qleaf(tree):
            return {"w": dequant_leaf(tree, dtype), "axes": tree["axes"]}
        if isinstance(tree, dict):
            return {k: visit(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(visit(v) for v in tree)
        return tree
    return visit(params)


# --------------------------------------------------------------------------
# matmul over quantized leaves
# --------------------------------------------------------------------------

def _pad_to(x, mult, axes):
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        r = (-x.shape[ax]) % mult
        if r:
            pads[ax] = (0, r)
    return jnp.pad(x, pads) if any(p != (0, 0) for p in pads) else x


def quant_matmul(x, ql: dict, compute_dtype=jnp.float32, *,
                 block: int = 32):
    """y = x @ dequant(ql), with the per-channel pow2 scale folded into
    the output (exact: 2^e scaling distributes exactly over the f32 sum).

    ``backend="xla"``: decode the words to f32 and run the baseline dot
    (weights-only quantization — bit-wise the same as dequantizing the
    whole matrix up front).  ``backend="pallas"``: encode x to the same
    format and call the PR-2 Pallas GEMM on the word operands directly
    (in-kernel decode, f32 accumulation — activations round to the
    lattice, the native posit serving semantics)."""
    fmt_name, backend = ql["qmeta"]
    fmt = get_format(fmt_name)
    words = jnp.asarray(ql["qw"], jnp.int32)
    scale = jnp.exp2(ql["sexp"].astype(jnp.float32))
    lead = x.shape[:-1]
    d_in, d_out = words.shape[-2], words.shape[-1]

    if backend == "pallas":
        from repro.kernels.posit_gemm import posit_gemm_f32
        x2 = x.reshape(-1, d_in).astype(jnp.float32)
        xw = posit.from_float32_bits(x2, fmt)
        ap = _pad_to(xw, block, (0, 1))
        bp = _pad_to(words, block, (0, 1))
        y = posit_gemm_f32(ap, bp, bm=block, bn=block, bk=block,
                           mode="split3", fmt=fmt)[:x2.shape[0], :d_out]
        y = y * scale
        return y.reshape(lead + (d_out,)).astype(compute_dtype)

    w = posit.to_float32_bits(words, fmt)
    y = jnp.dot(x.astype(compute_dtype), w.astype(compute_dtype),
                preferred_element_type=jnp.float32)
    return (y * scale).astype(compute_dtype)


# --------------------------------------------------------------------------
# storage accounting (the HBM-bytes evidence for bench_serve)
# --------------------------------------------------------------------------

def param_bytes(params) -> dict:
    """{"bytes": stored bytes, "f32_bytes": the f32-equivalent bytes,
    "word_bytes": posit word bytes only, "scale_bytes": sexp overhead,
    "q_f32_bytes": f32-equivalent of the quantized leaves alone (so
    q_f32_bytes / word_bytes is exactly the wire-width ratio: 2x for
    p16e1, 4x for p8e2)}.  Quantized leaves count their wire words +
    int8 scale exponents; everything else counts its actual bytes."""
    tot = {"bytes": 0, "f32_bytes": 0, "word_bytes": 0, "scale_bytes": 0,
           "q_f32_bytes": 0}

    def visit(tree):
        if is_qleaf(tree):
            n = int(np.prod(tree["qw"].shape))
            wb = n * tree["qw"].dtype.itemsize
            sb = int(np.prod(tree["sexp"].shape))
            tot["word_bytes"] += wb
            tot["scale_bytes"] += sb
            tot["bytes"] += wb + sb
            tot["f32_bytes"] += n * 4
            tot["q_f32_bytes"] += n * 4
            return
        if is_param(tree):
            nb = int(np.prod(tree["w"].shape)) * 4
            tot["bytes"] += nb
            tot["f32_bytes"] += nb
            return
        if isinstance(tree, dict):
            for v in tree.values():
                visit(v)
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                visit(v)

    visit(params)
    return tot


@functools.lru_cache(maxsize=None)
def golden_zone_fraction_fn(fmt_name: str):
    """Jitted golden-zone occupancy of a word array (regime k in {0,-1})
    — the PR-6 positscope measure, reused for quantized-weight evidence."""
    fmt = get_format(fmt_name)

    def f(words):
        p = jnp.asarray(words, jnp.int32).ravel()
        is_zero, is_nar, _, scale, _ = posit.decode(p, fmt)
        finite = ~(is_zero | is_nar)
        k = scale >> fmt.es
        golden = finite & (k >= -1) & (k <= 0)
        nfin = jnp.maximum(jnp.sum(finite.astype(jnp.int64)), 1)
        return jnp.sum(golden.astype(jnp.float64)) / nfin
    return jax.jit(f)


def weight_golden_zone(params) -> float:
    """Mean golden-zone occupancy over all quantized leaves (weighted by
    element count)."""
    occ, n = 0.0, 0

    def visit(tree):
        nonlocal occ, n
        if is_qleaf(tree):
            fmt_name, _ = tree["qmeta"]
            sz = int(np.prod(tree["qw"].shape))
            occ += float(golden_zone_fraction_fn(fmt_name)(
                jnp.asarray(tree["qw"], jnp.int32))) * sz
            n += sz
            return
        if isinstance(tree, dict):
            for v in tree.values():
                visit(v)
        elif isinstance(tree, (list, tuple)):
            for v in tree:
                visit(v)

    visit(params)
    return occ / max(n, 1)
