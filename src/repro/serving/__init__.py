"""Posit-quantized LLM serving: weight quantization, paged posit
KV-cache, continuous batching, synthetic traffic replay."""
from repro.serving.engine import (Engine, Request, generate, prefill,
                                  prefill_loop)
from repro.serving.kv_cache import PagedKVSpec, PagePool
from repro.serving.quantize import (QuantConfig, dequantize_params,
                                    param_bytes, quantize_params,
                                    weight_golden_zone)
from repro.serving.traffic import TrafficConfig, replay, synth_trace

__all__ = [
    "Engine", "Request", "generate", "prefill", "prefill_loop",
    "PagedKVSpec", "PagePool", "QuantConfig", "dequantize_params",
    "param_bytes", "quantize_params", "weight_golden_zone",
    "TrafficConfig", "replay", "synth_trace",
]
