from repro.serving.engine import generate, prefill

__all__ = ["generate", "prefill"]
