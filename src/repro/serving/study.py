"""Quantized-inference accuracy study (the ``error_eval`` of serving).

Measures what posit weight quantization costs in logits, per format,
against the f32 reference AND the bf16 cast that is the industry
default at the same width — the paper's accuracy-per-bit claim stated
on the serving workload.  Correlates the error with golden-zone
occupancy of the quantized words (PR-6 positscope measure): per-channel
pow2 equilibration pushes weights into the golden zone, and the error
drop it buys is the mechanism, not a coincidence.

Metrics per (arch, format, equilibration) cell, on tiny-scale models
(same layer topology as the real configs, seconds on CPU):

* ``rel_err``  — ||logits_q - logits_f32|| / ||logits_f32||
* ``kl``       — mean KL(softmax_f32 || softmax_q), a perplexity proxy
  (it is exactly the excess cross-entropy of the quantized model
  against the reference model's next-token distribution)
* ``top1``     — argmax agreement fraction (greedy-decode stability)
* ``gz``       — element-weighted golden-zone occupancy of the words
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_tiny_config
from repro.models import forward_prefill, init_params
from repro.serving.quantize import (QuantConfig, quantize_params,
                                    weight_golden_zone)

STUDY_ARCHS = ("qwen2-0.5b", "mamba2-780m")
STUDY_FMTS = ("p32e2", "p16e1", "p8e2")


def _logit_metrics(ref, q):
    ref = jnp.asarray(ref, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    rel = (jnp.linalg.norm(q - ref)
           / jnp.maximum(jnp.linalg.norm(ref), 1e-30))
    lp_ref = jax.nn.log_softmax(ref, axis=-1)
    lp_q = jax.nn.log_softmax(q, axis=-1)
    kl = jnp.mean(jnp.sum(jnp.exp(lp_ref) * (lp_ref - lp_q), axis=-1))
    top1 = jnp.mean((jnp.argmax(ref, -1) == jnp.argmax(q, -1))
                    .astype(jnp.float32))
    return float(rel), float(kl), float(top1)


def _bf16_params(params):
    """The bf16-storage reference: the same leaves the posit quantizer
    touches, rounded to bf16 instead."""
    from repro.models.common import is_param
    from repro.serving.quantize import QUANT_LEAF_KEYS

    def visit(tree, name):
        if is_param(tree):
            if name not in QUANT_LEAF_KEYS or jnp.ndim(tree["w"]) < 2:
                return tree
            return {"w": tree["w"].astype(jnp.bfloat16)
                    .astype(jnp.float32), "axes": tree["axes"]}
        if isinstance(tree, dict):
            return {k: visit(v, k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(visit(v, name) for v in tree)
        return tree
    return visit(params, "")


def quant_study(arch_ids=STUDY_ARCHS, fmts=STUDY_FMTS, *, seed: int = 0,
                batch: int = 2, seq: int = 16) -> list[dict]:
    """Rows of {"arch", "fmt", "equilibrated", rel_err, kl, top1, gz}.
    ``fmt`` "f32" is the (identity) reference row, "bf16" the cast."""
    rows = []
    for arch in arch_ids:
        cfg = get_tiny_config(arch, policy="f32")
        key = jax.random.PRNGKey(seed)
        params = init_params(key, cfg)
        toks = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                  (batch, seq), 0, cfg.vocab)
        lbatch = {"tokens": toks}
        ref = forward_prefill(params, lbatch, cfg)

        rel, kl, top1 = _logit_metrics(ref, forward_prefill(
            _bf16_params(params), lbatch, cfg))
        rows.append({"arch": cfg.name, "fmt": "bf16", "equilibrated": "-",
                     "rel_err": rel, "kl": kl, "top1": top1, "gz": None})

        for fmt in fmts:
            for per_channel in (True, False):
                qp = quantize_params(
                    params, QuantConfig(fmt=fmt, per_channel=per_channel))
                out = forward_prefill(qp, lbatch, cfg)
                rel, kl, top1 = _logit_metrics(ref, out)
                rows.append({
                    "arch": cfg.name, "fmt": fmt,
                    "equilibrated": "yes" if per_channel else "no",
                    "rel_err": rel, "kl": kl, "top1": top1,
                    "gz": weight_golden_zone(qp)})
    return rows


def study_table(rows: list[dict]) -> str:
    """Markdown table of the study rows."""
    out = ["| arch | fmt | equil | rel_err | KL | top1 | gz |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        gz = "-" if r["gz"] is None else f"{r['gz']:.3f}"
        out.append(
            f"| {r['arch']} | {r['fmt']} | {r['equilibrated']} "
            f"| {r['rel_err']:.3e} | {r['kl']:.3e} "
            f"| {r['top1']:.3f} | {gz} |")
    return "\n".join(out)
