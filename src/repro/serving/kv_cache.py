"""Paged posit-word KV-cache for the continuous-batching engine.

The KV-cache is the second HBM consumer in serving (after the weights)
and the first one that grows with load: bytes = layers x tokens x heads
x head_dim x width.  Storing K/V as posit words in the format's wire
dtype (int16 for p16e1 — the bf16-position posit) halves KV HBM against
f32 at the golden-zone accuracy the repo has quantified; paging it in
fixed-size blocks means a request only holds the pages its length
needs, so heterogeneous-length batches don't pay max-length rectangles
(the vLLM PagedAttention argument, in posit words).

Layout
------
Per attention slot (period-slot kinds ``attn``/``local``; SSM state and
the hybrid shared block stay dense f32 in the engine, they are O(1) of
the stack), one pool pair::

    k_pool, v_pool : (np_, n_pages * page_size, n_kv_heads, d_head)

in the storage dtype (``fmt.wire_dtype``, or f32 when ``fmt is None``
— the unquantized baseline uses the same machinery).  ``np_`` is the
stacked layer-group dim the model scan slices.

A shared **block table** (max_batch, max_pages) int32 maps each
request row's page index to a physical page; -1 means unallocated and
gathers **page 0**, the reserved zero page that is never written.
Pages are allocated in positional order, so row ``b``'s gathered dense
cache is position-contiguous: gathered slot ``s`` holds absolute
position ``s`` — exactly the (non-ring) dense cache ``serve_step``
expects, which is what makes batched decode bit-identical to the dense
path.  Slots past the row's valid length hold stale-but-finite words
and are masked exactly in attention (kv_valid_len), so they never leak.

Scatters address the flattened pool by linear index ``page * page_size
+ offset`` with ``mode="drop"``: inactive rows (and prefill padding)
scatter to an out-of-bounds index and are dropped deterministically —
no trash pages, no cross-row collisions.

The allocator is host-side (a free list + the numpy block table): page
churn is O(requests), not O(tokens), and stays off the hot path.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import posit
from repro.core.formats import get_format
from repro.models.common import ArchConfig
from repro.models.lm import period_of, slot_kinds


@dataclasses.dataclass(frozen=True)
class PagedKVSpec:
    """Static shape of a paged pool set."""
    page_size: int = 16
    n_pages: int = 64            # physical pages (incl. reserved page 0)
    max_batch: int = 4           # decode width (static — bit-identity)
    max_pages: int = 8           # block-table columns = max seq / page_size
    fmt: str | None = "p16e1"    # wire storage; None = f32 baseline

    @property
    def s_gather(self) -> int:
        """Dense gathered length (= max supported sequence length)."""
        return self.max_pages * self.page_size

    def pages_for(self, seq_len: int) -> int:
        return -(-seq_len // self.page_size)


def kv_slot_indices(cfg: ArchConfig) -> list[int]:
    """Period-slot indices that carry an attention KV cache."""
    return [j for j, k in enumerate(slot_kinds(cfg))
            if k in ("attn", "local")]


def encode_kv(x, fmt_name: str | None):
    """f32 K/V -> storage words (identity when fmt is None)."""
    if fmt_name is None:
        return jnp.asarray(x, jnp.float32)
    fmt = get_format(fmt_name)
    return posit.from_float32_bits(
        jnp.asarray(x, jnp.float32), fmt).astype(fmt.wire_dtype)


def decode_kv(w, fmt_name: str | None, dtype=jnp.float32):
    """storage words -> f32 K/V (identity when fmt is None)."""
    if fmt_name is None:
        return jnp.asarray(w, dtype)
    fmt = get_format(fmt_name)
    return posit.to_float32_bits(
        jnp.asarray(w, jnp.int32), fmt).astype(dtype)


def gather_linear_indices(block_table, page_size: int):
    """(B, P) block table -> (B, P*page_size) linear pool indices.
    Unallocated (-1) pages map to page 0 (the zero page)."""
    bt = jnp.maximum(jnp.asarray(block_table, jnp.int32), 0)
    off = jnp.arange(page_size, dtype=jnp.int32)
    lin = bt[:, :, None] * page_size + off[None, None, :]
    return lin.reshape(bt.shape[0], -1)


def gather_dense(pool, lin_idx, fmt_name, dtype=jnp.float32):
    """pool (np_, n_pages*ps, H, D) + lin (B, Sg) -> dense
    (np_, B, Sg, H, D) decoded K/V."""
    g = pool[:, lin_idx]                       # (np_, B, Sg, H, D)
    return decode_kv(g, fmt_name, dtype)


def scatter_rows(pool, idx, rows, fmt_name):
    """Write one (np_, B, H, D) row batch into the flat pool at linear
    indices idx (B,); out-of-bounds indices (inactive rows, padding)
    are dropped deterministically."""
    words = encode_kv(rows, fmt_name)
    # inactive rows share the out-of-bounds sentinel, so indices are NOT
    # unique — mode="drop" discards them deterministically
    return pool.at[:, idx].set(words.astype(pool.dtype), mode="drop")


class PagePool:
    """Host-side page allocator + the device pools for every KV slot.

    Functional on the device side: the engine's jitted step takes the
    pools dict and returns an updated one; this object owns allocation
    (free list, block table) and the current device arrays.
    """

    def __init__(self, cfg: ArchConfig, spec: PagedKVSpec):
        self.cfg, self.spec = cfg, spec
        np_ = cfg.n_layers // period_of(cfg)
        dt = (jnp.float32 if spec.fmt is None
              else jnp.dtype(get_format(spec.fmt).wire_dtype))
        shape = (np_, spec.n_pages * spec.page_size,
                 cfg.n_kv_heads, cfg.d_head)
        self.pools = {
            j: {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
            for j in kv_slot_indices(cfg)}
        # page 0 is the reserved zero page
        self.free: list[int] = list(range(1, spec.n_pages))
        self.block_table = np.full((spec.max_batch, spec.max_pages),
                                   -1, np.int32)

    # -- allocation (host) -------------------------------------------------
    def can_alloc(self, n_pages: int) -> bool:
        return len(self.free) >= n_pages

    def alloc_row(self, row: int, n_pages: int) -> None:
        """Reserve n_pages for request row (positional order)."""
        assert n_pages <= self.spec.max_pages, (n_pages, self.spec)
        assert self.can_alloc(n_pages), "page pool exhausted"
        assert (self.block_table[row] == -1).all(), f"row {row} not free"
        for i in range(n_pages):
            self.block_table[row, i] = self.free.pop()

    def free_row(self, row: int) -> None:
        for p in self.block_table[row]:
            if p >= 0:
                self.free.append(int(p))
        self.block_table[row] = -1

    def pages_in_use(self) -> int:
        return int((self.block_table >= 0).sum())

    def linear_index(self, row: int, pos: int) -> int:
        """Linear pool index of (row, absolute position); OOB sentinel
        (= dropped scatter) if the position has no page."""
        ps = self.spec.page_size
        page = self.block_table[row, pos // ps]
        if page < 0:
            return self.spec.n_pages * ps          # out of bounds -> drop
        return int(page) * ps + pos % ps

    # -- accounting --------------------------------------------------------
    def bytes(self) -> dict:
        """Stored pool bytes vs the f32-equivalent (the HBM evidence)."""
        b = f32 = 0
        for kv in self.pools.values():
            for a in kv.values():
                n = int(np.prod(a.shape))
                b += n * a.dtype.itemsize
                f32 += n * 4
        return {"bytes": b, "f32_bytes": f32}
