"""Synthetic traffic generation + replay for the serving engine.

Real serving load is bursty and heterogeneous; the replay driver feeds
the engine a seeded synthetic trace (Poisson-ish arrivals, geometric
prompt/output lengths) step by step, so continuous batching actually
interleaves requests at different depths — the regime the bit-identity
gate and the throughput numbers in ``bench_serve`` are claimed for.
Everything is deterministic in the seed: the same trace replays against
every (format, batch) cell.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serving.engine import Engine, Request


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 16
    mean_plen: int = 12          # mean prompt length (>= 1)
    mean_new: int = 8            # mean generation length (>= 1)
    arrival_rate: float = 0.5    # expected request arrivals per step
    vocab: int = 128
    seed: int = 0


def synth_trace(tc: TrafficConfig) -> list[Request]:
    """Seeded synthetic request trace, sorted by arrival step."""
    rng = np.random.default_rng(tc.seed)
    step = 0
    out = []
    for rid in range(tc.n_requests):
        step += int(rng.geometric(min(max(tc.arrival_rate, 1e-6), 1.0)))
        plen = 1 + int(rng.poisson(max(tc.mean_plen - 1, 0)))
        max_new = 1 + int(rng.poisson(max(tc.mean_new - 1, 0)))
        prompt = rng.integers(0, tc.vocab, size=(plen,)).astype(np.int32)
        out.append(Request(rid=rid, prompt=prompt, max_new=max_new,
                           arrival=step))
    return out


def replay(engine: Engine, trace: list[Request],
           max_steps: int = 100000) -> dict:
    """Feed the trace into the engine respecting arrival steps; returns
    the throughput report (wall-clock tokens/sec + requests/sec) and
    the per-request outputs keyed by rid."""
    pending = sorted(trace, key=lambda r: r.arrival)
    step = 0
    occ_sum = 0.0
    t0 = time.perf_counter()
    while pending or engine.queue or engine.n_inflight():
        while pending and pending[0].arrival <= step:
            engine.submit(pending.pop(0))
        engine.step()
        occ_sum += engine.n_inflight() / engine.spec.max_batch
        step += 1
        if step >= max_steps:
            raise RuntimeError("replay did not drain")
    wall = time.perf_counter() - t0
    outputs = dict(engine.finished)
    tokens = int(sum(len(v) for v in outputs.values()))
    return {
        "requests": len(outputs),
        "tokens": tokens,
        "steps": step,
        "wall_s": wall,
        "tok_s": tokens / wall if wall > 0 else float("inf"),
        "req_s": len(outputs) / wall if wall > 0 else float("inf"),
        "occupancy": occ_sum / max(step, 1),
        "outputs": outputs,
    }
