"""Serving engine: scan prefill + continuous batching over a paged
posit KV-cache.

Two layers:

* the original small surface — ``prefill`` / ``generate`` — a jitted
  per-token greedy decode over the dense ring caches from
  ``repro.models.lm`` (the dry-run's ``serve_step`` cells lower exactly
  this step).  ``prefill`` is now a single ``lax.scan`` dispatch
  (bit-identical to the per-token Python loop it replaced, which
  survives as ``prefill_loop`` and is pinned in tests).

* ``Engine`` — the continuous-batching engine: requests are admitted
  into a fixed ``max_batch``-wide decode step as pages free up, decode
  runs every in-flight request one token per step against the paged
  posit-word KV pools (``serving.kv_cache``), and finished requests
  release their pages immediately.  Weights may be posit-quantized
  (``serving.quantize``) — the quantized leaves flow through
  ``serve_step`` untouched here.

Bit-identity argument (gated in bench_serve before any timing): the
decode step is ONE jitted program at a FIXED batch width — row
contents never influence other rows (row-wise matmul/attention/scan
independence at fixed width), inactive rows are padding whose scatters
drop out of bounds, and a request's gathered dense cache is
position-contiguous regardless of which physical pages back it.  The
sequential reference is therefore *the same engine* with admission
capped at one in-flight request — same program, same width — and the
generated tokens match bit-for-bit.

Rounding contract for posit KV: a step's incoming K/V enters its own
attention in f32 (it is written into the gathered dense cache inside
``serve_step``) and is rounded to the posit lattice once, at the pool
scatter; every later step reads the rounded words.  Sequential and
batched decode round identically, so the contract costs no identity.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import init_cache, serve_step
from repro.models.common import ArchConfig
from repro.models.lm import period_of, slot_kinds
from repro.models import ssm as ssm_mod
from repro.serving.kv_cache import (PagedKVSpec, PagePool, gather_dense,
                                    gather_linear_indices, kv_slot_indices,
                                    scatter_rows)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _step(params, cache, tok, pos, cfg: ArchConfig):
    logits, cache = serve_step(params, cache, tok, pos, cfg)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return nxt, cache


# --------------------------------------------------------------------------
# prefill: one scanned dispatch (legacy per-token loop kept for the pin)
# --------------------------------------------------------------------------

def _build_cross_kv(params, cfg, cache, extras):
    from repro.models import attention as attn_mod
    from repro.models.lm import _encoder
    policy = cfg.get_policy()
    dtype = jnp.dtype(policy.compute_dtype)
    enc = _encoder(params, extras["frames"], cfg, policy, dtype)
    # stacked (n_layers, ...) cross-KV computed from the stacked slot-0
    # decoder params (encdec has period 1)
    cache["cross_kv"] = jax.vmap(
        lambda lp: attn_mod.cross_kv_init(lp["xattn"], enc, cfg, policy,
                                          dtype)
    )(params["layers"][0])
    return cache


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_scan(params, cache, prompts, plen, cfg: ArchConfig):
    """Scan the decode step over the prompt.  ``prompts`` (B, nsteps)
    may be padded past the (traced) valid length ``plen``: steps at
    i >= plen freeze the carry, so the returned cache and last-token
    prediction pin at exactly ``plen`` — one compiled program serves
    every prompt length in a padding bucket."""
    nsteps = prompts.shape[1]
    toks = jnp.swapaxes(prompts, 0, 1)[:, :, None].astype(jnp.int32)
    steps = jnp.arange(nsteps, dtype=jnp.int32)
    last0 = jnp.zeros((prompts.shape[0], 1), jnp.int32)

    def body(carry, inp):
        cache, last = carry
        tok, i = inp
        logits, new_cache = serve_step(params, cache, tok, i, cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        keep = i < plen
        new_cache = jax.tree.map(
            lambda n, o: jnp.where(keep, n, o), new_cache, cache)
        last = jnp.where(i == plen - 1, nxt, last)
        return (new_cache, last), None

    (cache, last), _ = jax.lax.scan(body, (cache, last0), (toks, steps))
    return cache, last


def prefill(params, cfg: ArchConfig, prompts: np.ndarray, cache_len: int,
            extras: dict[str, Any] | None = None):
    """Feed prompt tokens through the decode path to fill the cache.

    prompts: (B, P) int32.  Returns (cache, last_token, next_pos).
    One scanned dispatch (was: one jitted dispatch per token)."""
    b, plen = prompts.shape
    cache = init_cache(cfg, b, cache_len)
    if cfg.family == "encdec":
        cache = _build_cross_kv(params, cfg, cache, extras)
    cache, tok = _prefill_scan(params, cache, jnp.asarray(prompts),
                               jnp.int32(plen), cfg)
    return cache, tok, plen


def prefill_loop(params, cfg: ArchConfig, prompts: np.ndarray,
                 cache_len: int, extras: dict[str, Any] | None = None):
    """The original per-token-dispatch prefill — kept as the reference
    the scanned version is pinned bit-identical against."""
    b, plen = prompts.shape
    cache = init_cache(cfg, b, cache_len)
    if cfg.family == "encdec":
        cache = _build_cross_kv(params, cfg, cache, extras)
    tok = jnp.asarray(prompts[:, :1], jnp.int32)
    for i in range(plen):
        nxt, cache = _step(params, cache, tok, jnp.int32(i), cfg)
        tok = jnp.asarray(prompts[:, i + 1:i + 2], jnp.int32) \
            if i + 1 < plen else nxt
    return cache, tok, plen


def generate(params, cfg: ArchConfig, prompts: np.ndarray, max_new: int = 16,
             cache_len: int | None = None, eos_id: int | None = None,
             extras: dict[str, Any] | None = None) -> np.ndarray:
    """Greedy decode: returns (B, max_new) generated token ids."""
    b, plen = prompts.shape
    cache_len = cache_len or (plen + max_new)
    cache, tok, pos = prefill(params, cfg, prompts, cache_len, extras)
    out = []
    done = np.zeros((b,), bool)
    for t in range(max_new):
        nxt, cache = _step(params, cache, tok, jnp.int32(pos + t), cfg)
        ids = np.asarray(nxt[:, 0])
        if eos_id is not None:
            done |= ids == eos_id
            ids = np.where(done, eos_id, ids)
        out.append(ids)
        tok = jnp.asarray(ids[:, None], jnp.int32)
        if eos_id is not None and done.all():
            break
    return np.stack(out, axis=1)


# --------------------------------------------------------------------------
# continuous-batching engine
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (plen,) int32
    max_new: int = 16
    eos_id: Optional[int] = None
    arrival: int = 0                   # traffic-replay step index


def _dense_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype):
    """Like ``init_cache`` but with NO ring truncation for local slots:
    the engine's gathered caches are position-contiguous over the full
    page span, so every KV slot is a flat (np_, B, seq_len, H, D)."""
    per = period_of(cfg)
    np_ = cfg.n_layers // per
    kinds = slot_kinds(cfg)

    def stack(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (np_,) + a.shape).copy(), tree)

    def slot(kind):
        if kind == "ssm":
            return stack({"ssm": ssm_mod.ssm_cache_init(cfg, batch, dtype)})
        z = jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.d_head), dtype)
        return stack({"kv": {"k": z, "v": z}})

    cache: dict[str, Any] = {"layers": [slot(kinds[j]) for j in range(per)]}
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        cache["shared"] = slot("shared")
    return cache


def _split_state(cfg, cache):
    """Engine-held dense state = everything that is NOT a paged KV slot
    (SSM conv/h state and the hybrid shared attention block)."""
    kinds = slot_kinds(cfg)
    state = {"ssm": {j: cache["layers"][j]
                     for j, k in enumerate(kinds) if k == "ssm"}}
    if "shared" in cache:
        state["shared"] = cache["shared"]
    return state


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _engine_step(params, pools, state, bt, tok, pos, scatter_idx,
                 cfg: ArchConfig, spec: PagedKVSpec):
    """One continuous-batching decode step at the static batch width.

    Gather each row's pages into a position-contiguous dense cache,
    run ``serve_step`` with per-row positions, then encode the new K/V
    rows to posit words and scatter them back into the pools (inactive
    rows scatter out of bounds and drop)."""
    dtype = jnp.dtype(cfg.get_policy().compute_dtype)
    kinds = slot_kinds(cfg)
    lin = gather_linear_indices(bt, spec.page_size)

    layers = []
    for j, kind in enumerate(kinds):
        if kind == "ssm":
            layers.append(state["ssm"][j])
        else:
            layers.append({"kv": {
                "k": gather_dense(pools[j]["k"], lin, spec.fmt, dtype),
                "v": gather_dense(pools[j]["v"], lin, spec.fmt, dtype)}})
    cache: dict[str, Any] = {"layers": layers}
    if "shared" in state:
        cache["shared"] = state["shared"]

    logits, new_cache = serve_step(params, cache, tok, pos, cfg)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

    idx5 = pos.reshape(1, -1, 1, 1, 1)
    new_pools = {}
    for j in kv_slot_indices(cfg):
        kv = new_cache["layers"][j]["kv"]
        rk = jnp.take_along_axis(kv["k"], idx5, axis=2)[:, :, 0]
        rv = jnp.take_along_axis(kv["v"], idx5, axis=2)[:, :, 0]
        new_pools[j] = {
            "k": scatter_rows(pools[j]["k"], scatter_idx, rk, spec.fmt),
            "v": scatter_rows(pools[j]["v"], scatter_idx, rv, spec.fmt)}
    new_state = _split_state(cfg, new_cache)
    return nxt, logits, new_pools, new_state


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _admit_write(pools, state, pcache, lin_idx, row,
                 cfg: ArchConfig, spec: PagedKVSpec):
    """Install a prefilled request: scatter its prompt K/V (encoded to
    the storage format) into the row's pages and copy its dense state
    (SSM / shared block) into engine row ``row``.  ``lin_idx`` covers
    the (bucket-padded) prompt span; pad entries are out of bounds."""
    nb = lin_idx.shape[0]
    new_pools = {}
    for j in kv_slot_indices(cfg):
        kv = pcache["layers"][j]["kv"]
        new_pools[j] = {
            "k": _scatter_span(pools[j]["k"], lin_idx,
                               kv["k"][:, 0, :nb], spec.fmt),
            "v": _scatter_span(pools[j]["v"], lin_idx,
                               kv["v"][:, 0, :nb], spec.fmt)}
    pstate = _split_state(cfg, pcache)
    new_state = jax.tree.map(
        lambda s, p: s.at[:, row].set(p[:, 0].astype(s.dtype)),
        state, pstate)
    return new_pools, new_state


def _scatter_span(pool, lin_idx, span, fmt_name):
    """Write (np_, nb, H, D) span rows at linear indices (nb,) —
    out-of-bounds (padding) entries drop."""
    from repro.serving.kv_cache import encode_kv
    words = encode_kv(span, fmt_name)
    return pool.at[:, lin_idx].set(words.astype(pool.dtype), mode="drop")


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class Engine:
    """Continuous-batching serving engine over paged posit KV pools.

    ``max_inflight`` caps concurrently decoding requests (the
    sequential bit-identity reference is ``max_inflight=1`` — same
    jitted program, same static width).  ``kv_fmt`` selects the KV
    storage format (None = f32 baseline); weight quantization is
    orthogonal (pass posit-quantized params).
    """

    def __init__(self, params, cfg: ArchConfig, *, max_batch: int = 4,
                 page_size: int = 16, max_seq: int = 128,
                 n_pages: int | None = None, kv_fmt: str | None = None,
                 max_inflight: int | None = None):
        if cfg.family in ("encdec", "vlm"):
            raise NotImplementedError(
                f"Engine does not serve {cfg.family} yet (extras "
                "plumbing); use serving.generate")
        max_pages = -(-max_seq // page_size)
        if n_pages is None:
            n_pages = max_batch * max_pages + 1      # + the zero page
        self.params, self.cfg = params, cfg
        self.spec = PagedKVSpec(page_size=page_size, n_pages=n_pages,
                                max_batch=max_batch, max_pages=max_pages,
                                fmt=kv_fmt)
        self.pool = PagePool(cfg, self.spec)
        self.max_inflight = min(max_inflight or max_batch, max_batch)
        self.dtype = jnp.dtype(cfg.get_policy().compute_dtype)
        self.state = _split_state(
            cfg, _dense_cache(cfg, max_batch, self.spec.s_gather,
                              self.dtype))
        self.queue: list[Request] = []
        self.slots: list[Optional[dict]] = [None] * max_batch
        self.tokens = np.zeros((max_batch, 1), np.int32)
        self.pos = np.zeros((max_batch,), np.int32)
        self.finished: dict[int, np.ndarray] = {}
        self.step_count = 0
        self._oob = self.spec.n_pages * self.spec.page_size

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> None:
        assert len(req.prompt) >= 1
        assert len(req.prompt) + req.max_new + 1 <= self.spec.s_gather, (
            "request exceeds engine max_seq")
        self.queue.append(req)

    def n_inflight(self) -> int:
        return sum(s is not None for s in self.slots)

    def _admit(self, req: Request, row: int) -> None:
        plen = len(req.prompt)
        need = self.spec.pages_for(plen + req.max_new + 1)
        self.pool.alloc_row(row, need)
        nb = _bucket(plen)
        padded = np.zeros((1, nb), np.int32)
        padded[0, :plen] = req.prompt
        cache0 = _dense_cache(self.cfg, 1, self.spec.s_gather, self.dtype)
        cache1, last = _prefill_scan(self.params, cache0,
                                     jnp.asarray(padded),
                                     jnp.int32(plen), self.cfg)
        lin = np.asarray(
            [self.pool.linear_index(row, t) if t < plen else self._oob
             for t in range(nb)], np.int32)
        self.pool.pools, self.state = _admit_write(
            self.pool.pools, self.state, cache1, jnp.asarray(lin),
            jnp.int32(row), self.cfg, self.spec)
        self.slots[row] = {"req": req, "out": []}
        self.tokens[row] = np.asarray(last)[0]
        self.pos[row] = plen

    def _finish(self, row: int) -> None:
        slot = self.slots[row]
        self.finished[slot["req"].rid] = np.asarray(slot["out"], np.int32)
        self.pool.free_row(row)
        self.slots[row] = None

    # -- stepping ----------------------------------------------------------
    def _try_admit(self) -> None:
        while self.queue and self.n_inflight() < self.max_inflight:
            req = self.queue[0]
            need = self.spec.pages_for(len(req.prompt) + req.max_new + 1)
            if not self.pool.can_alloc(need):
                break
            row = self.slots.index(None)
            self.queue.pop(0)
            self._admit(req, row)

    def step(self) -> list[int]:
        """Admit what fits, decode one token for every in-flight
        request, retire finished ones.  Returns rids finished this
        step."""
        self._try_admit()
        self.step_count += 1
        active = [b for b, s in enumerate(self.slots) if s is not None]
        obs.inc("serve.steps")
        obs.gauge("serve.batch_occupancy",
                  len(active) / self.spec.max_batch)
        obs.gauge("serve.kv_pages_in_use", self.pool.pages_in_use())
        if not active:
            return []
        scatter_idx = np.full((self.spec.max_batch,), self._oob, np.int32)
        for b in active:
            scatter_idx[b] = self.pool.linear_index(b, int(self.pos[b]))
        nxt, _, self.pool.pools, self.state = _engine_step(
            self.params, self.pool.pools, self.state,
            jnp.asarray(self.pool.block_table), jnp.asarray(self.tokens),
            jnp.asarray(self.pos), jnp.asarray(scatter_idx),
            self.cfg, self.spec)
        nxt = np.asarray(nxt)
        obs.inc("serve.tokens", len(active))
        done_rids = []
        for b in active:
            slot = self.slots[b]
            req = slot["req"]
            tid = int(nxt[b, 0])
            slot["out"].append(tid)
            self.tokens[b] = tid
            self.pos[b] += 1
            if (len(slot["out"]) >= req.max_new
                    or (req.eos_id is not None and tid == req.eos_id)):
                done_rids.append(req.rid)
                self._finish(b)
        return done_rids

    def run(self, requests: list[Request], max_steps: int = 10000
            ) -> dict[int, np.ndarray]:
        """Serve a request list to completion; returns rid -> tokens."""
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.queue or self.n_inflight()) and steps < max_steps:
            self.step()
            steps += 1
        assert not self.queue and not self.n_inflight(), "did not drain"
        return dict(self.finished)

    # -- accounting --------------------------------------------------------
    def kv_bytes(self) -> dict:
        return self.pool.bytes()
