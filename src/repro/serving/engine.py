"""Batched serving engine: prefill + greedy/temperature decode.

Small but real: a jitted per-token step over the ring-buffer KV/state
caches from ``repro.models.lm``, with per-request stop handling.  The
dry-run's ``serve_step`` cells lower exactly the step used here.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache, serve_step
from repro.models.common import ArchConfig


@functools.partial(jax.jit, static_argnames=("cfg",))
def _step(params, cache, tok, pos, cfg: ArchConfig):
    logits, cache = serve_step(params, cache, tok, pos, cfg)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return nxt, cache


def prefill(params, cfg: ArchConfig, prompts: np.ndarray, cache_len: int,
            extras: dict[str, Any] | None = None):
    """Feed prompt tokens through the decode path to fill the cache.

    prompts: (B, P) int32.  Returns (cache, last_token, next_pos).
    """
    b, plen = prompts.shape
    cache = init_cache(cfg, b, cache_len)
    if cfg.family == "encdec":
        from repro.models import attention as attn_mod
        from repro.models.lm import _encoder
        policy = cfg.get_policy()
        dtype = jnp.dtype(policy.compute_dtype)
        enc = _encoder(params, extras["frames"], cfg, policy, dtype)
        # stacked (n_layers, ...) cross-KV computed from the stacked slot-0
        # decoder params (encdec has period 1)
        cache["cross_kv"] = jax.vmap(
            lambda lp: attn_mod.cross_kv_init(lp["xattn"], enc, cfg, policy,
                                              dtype)
        )(params["layers"][0])
    tok = jnp.asarray(prompts[:, :1], jnp.int32)
    for i in range(plen):
        nxt, cache = _step(params, cache, tok, jnp.int32(i), cfg)
        tok = jnp.asarray(prompts[:, i + 1:i + 2], jnp.int32) \
            if i + 1 < plen else nxt
    return cache, tok, plen


def generate(params, cfg: ArchConfig, prompts: np.ndarray, max_new: int = 16,
             cache_len: int | None = None, eos_id: int | None = None,
             extras: dict[str, Any] | None = None) -> np.ndarray:
    """Greedy decode: returns (B, max_new) generated token ids."""
    b, plen = prompts.shape
    cache_len = cache_len or (plen + max_new)
    cache, tok, pos = prefill(params, cfg, prompts, cache_len, extras)
    out = []
    done = np.zeros((b,), bool)
    for t in range(max_new):
        nxt, cache = _step(params, cache, tok, jnp.int32(pos + t), cfg)
        ids = np.asarray(nxt[:, 0])
        if eos_id is not None:
            done |= ids == eos_id
            ids = np.where(done, eos_id, ids)
        out.append(ids)
        tok = jnp.asarray(ids[:, None], jnp.int32)
        if eos_id is not None and done.all():
            break
    return np.stack(out, axis=1)
