"""Rgeqrf / Rormqr / Rorgqr / Rgels — blocked Householder QR and
quire-exact least-squares solvers in posit arithmetic (MPLAPACK naming).

The over-determined-system scenario on top of the existing stack: the
paper evaluates Posit(32,2) on Cholesky/LU (§5); least squares is the
dense workload where the golden-zone accuracy story matters most, since
forming the normal equations squares the backward error.  Householder QR
avoids A^T A entirely, and the quire turns the remaining error sources
(triangular solves, residuals) into single-rounding fused ops.

Algorithms (right-looking LAPACK, compact-WY):

* ``geqr2``  — unblocked panel (dgeqr2/dlarfg op order): every scalar op
  is a rounded posit op in fused-chain form (decode once, ``chain_round``
  each op, encode once — bit-identical to per-op word arithmetic).
* ``larft``  — forward columnwise T factor of the block reflector
  H_0 ... H_{w-1} = I - V T V^T, rounded-chain in dlarft's op order.
* ``rgeqrf`` — blocked driver: panel + three ``ops.rgemm`` calls per
  block (larfb: W = V^T C; W = T^T W; C -= V W) — the same offload split
  as ``rpotrf``/``rgetrf``, so the trailing-update flops ride whichever
  accelerator backend ``gemm_backend`` selects (quire_exact, xla_quire,
  the fused-encode Pallas kernel, faithful).  The block schedule is
  static at trace time: ``rgeqrf`` is ONE jitted XLA dispatch;
  ``rgeqrf_loop`` keeps the dispatch-per-block Python driver as the
  bit-identical measured baseline (benchmarks/bench_qr.py), and
  ``rgeqrf_batched`` vmaps the same program over a leading matrix axis.
* ``rormqr`` / ``rorgqr`` — apply Q/Q^T from the stored reflectors /
  materialize Q explicitly.  They rebuild V and T from the factored
  words, and chain values round-trip the word encode exactly, so the
  T each block applies is bit-identical to the one ``rgeqrf`` used.
* ``rgels``  — over-determined solve (m >= n): x = R^{-1} (Q^T b)[:n].
* ``rgels_ir`` / ``rgels_mp`` — quire-exact iterative refinement of the
  least-squares solution through ``refine.refine_pair``'s
  ``solve_fn``/``residual_fn`` extension points.  The residual
  r = b - A(x_hi + x_lo) is exact per component (one rounding); the
  correction solves min ||A d - r|| by the semi-normal equations
  R^T R d = A^T r with a quire-exact A^T r (``quire_gemv``) and
  quire-backed triangular sweeps — refinement makes semi-normal
  equations backward-stable (Björck's CSNE), and the PR-4 power-of-two
  equilibrations (matrix before factorization, residual per sweep) make
  the contraction sigma-invariant.  ``rgels_mp`` factorizes in a cheap
  narrow format (default Posit(16,1)) and refines with working-format
  quire residuals — the HPL-AI trade on the LS scenario.  See
  DESIGN.md §9.

All matrices are int32 posit words of the static format ``fmt``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P16E1, P32E2, PositFormat
from repro.kernels.ops import rgemm
from repro.lapack import refine
from repro.lapack import solve
from repro.lapack.blas import rlarfg_chain, rtrsm_left_upper
from repro.obs import metrics as _obs_metrics
from repro.obs import numerics as _obs_numerics
from repro.obs import trace as _obs_trace
from repro.quire import quire_gemv


# --------------------------------------------------------------------------
# unblocked panel (all-posit, fused-chain form)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("fmt",))
def geqr2(a_p: jax.Array, fmt: PositFormat = P32E2):
    """Unblocked Householder QR of an (m, w) posit panel, dgeqr2 op order.

    Returns (panel, tau): R on/above the diagonal, the reflectors' tails
    below it (v_k = 1 implicit), and the (w,) tau posit words.  Fused-
    chain execution: the panel decodes to f64 once, every scalar op is
    posit-rounded in place, words are packed once at exit.
    """
    m, w = a_p.shape
    rows = jnp.arange(m)
    cols = jnp.arange(w)
    a0 = posit.chain_decode(a_p, fmt)

    def step(carry, k):
        a, taus = carry
        newcol, v, tau = rlarfg_chain(a[:, k], k, fmt)
        # apply H = I - tau v v^T to the remaining columns (> k):
        # wvec = v^T A (row-ascending chained adds; v_k = 1 contributes
        # A[k, :] exactly), then A -= v (tau * wvec)  (rank-1, rounded)
        def accw(s, i):
            upd = posit.chain_add(s, posit.chain_mul(v[i], a[i, :], fmt),
                                  fmt)
            return jnp.where(i > k, upd, s), None

        wvec, _ = jax.lax.scan(accw, a[k, :], rows)
        t = posit.chain_mul(tau, wvec, fmt)
        upd = posit.chain_sub(a, posit.chain_mul(v[:, None], t[None, :],
                                                 fmt), fmt)
        mask = (rows >= k)[:, None] & (cols > k)[None, :]
        a = jnp.where(mask, upd, a)
        a = a.at[:, k].set(newcol)
        return (a, taus.at[k].set(tau)), None

    (a, taus), _ = jax.lax.scan(step, (a0, jnp.zeros((w,), jnp.float64)),
                                cols)
    return posit.chain_encode(a, fmt), posit.chain_encode(taus, fmt)


@functools.partial(jax.jit, static_argnames=("fmt",))
def larft(v_p: jax.Array, tau_p: jax.Array,
          fmt: PositFormat = P32E2) -> jax.Array:
    """Forward columnwise T of the block reflector (dlarft):
    H_0 ... H_{w-1} = I - V T V^T with T (w, w) upper-triangular.

    Rounded-chain evaluation in dlarft's op order: G = V^T V column dots
    (row-ascending chained adds; the unit-trapezoid zeros contribute
    exactly nothing), then per column j: T[:j, j] = T[:j, :j] @
    (-tau_j G[:j, j]) (chained trmv), T[j, j] = tau_j.
    """
    m, w = v_p.shape
    v = posit.chain_decode(v_p, fmt)
    tau = posit.chain_decode(tau_p, fmt)
    cols = jnp.arange(w)

    def accg(g, r):
        g = posit.chain_add(g, posit.chain_mul(v[r, :][:, None],
                                               v[r, :][None, :], fmt), fmt)
        return g, None

    g, _ = jax.lax.scan(accg, jnp.zeros((w, w)), jnp.arange(m))

    def tcol(t, j):
        h = posit.chain_mul(jnp.negative(tau[j]), g[:, j], fmt)

        def acct(s, el):
            upd = posit.chain_add(s, posit.chain_mul(t[:, el], h[el], fmt),
                                  fmt)
            return jnp.where(el < j, upd, s), None

        h2, _ = jax.lax.scan(acct, jnp.zeros((w,)), cols)
        newcol = jnp.where(cols < j, h2, jnp.where(cols == j, tau[j], 0.0))
        return t.at[:, j].set(newcol), None

    t, _ = jax.lax.scan(tcol, jnp.zeros((w, w)), cols)
    return posit.chain_encode(t, fmt)


def _v_words(panel_p: jax.Array, fmt: PositFormat) -> jax.Array:
    """Unit-lower-trapezoid reflector words V from a factored panel: the
    below-diagonal tails, an exact 1 on the diagonal, exact 0 above."""
    mj, w = panel_p.shape
    rows = jnp.arange(mj)[:, None]
    cols = jnp.arange(w)[None, :]
    one = posit.from_float64(jnp.float64(1.0), fmt)
    return jnp.where(rows > cols, panel_p,
                     jnp.where(rows == cols, one, 0))


def _r_words(qr_p: jax.Array, n: int) -> jax.Array:
    """Upper-triangular R words from a factored matrix (reflector tails
    below the diagonal zeroed; posit word 0 == value 0)."""
    tri = jnp.triu(jnp.ones((n, n), bool))
    return jnp.where(tri, qr_p[:n, :n], 0)


def _apply_block(c_p: jax.Array, v_w: jax.Array, t_w: jax.Array,
                 trans: bool, gemm_backend: str,
                 fmt: PositFormat) -> jax.Array:
    """larfb: C <- (I - V T V^T) C  (or the transpose, trans=True) as
    three Rgemm calls on the selected accelerator backend."""
    w1 = rgemm(v_w, c_p, trans_a=True, backend=gemm_backend, fmt=fmt)
    w2 = rgemm(t_w, w1, trans_a=trans, backend=gemm_backend, fmt=fmt)
    return rgemm(v_w, w2, c_p, alpha=-1.0, beta=1.0, backend=gemm_backend,
                 fmt=fmt)


# --------------------------------------------------------------------------
# blocked drivers — one traced body, three dispatch shapes (decomp.py idiom)
# --------------------------------------------------------------------------

def _rgeqrf_body(a_p: jax.Array, nb: int, gemm_backend: str,
                 fmt: PositFormat = P32E2, collect: bool = False):
    """Right-looking blocked Householder QR; schedule unrolled at trace.
    ``collect=True`` (the obs-variant program, see ``rgeqrf``) adds the
    per-block-step telemetry list (decomp.py convention)."""
    m, n = a_p.shape
    kk = min(m, n)
    a = jnp.asarray(a_p, jnp.int32)
    taus = jnp.zeros((kk,), jnp.int32)
    tel = []
    for j in range(0, kk, nb):
        w = min(nb, kk - j)
        panel, tau = geqr2(a[j:, j:j + w], fmt=fmt)
        a = a.at[j:, j:j + w].set(panel)
        taus = taus.at[j:j + w].set(tau)
        if collect:
            tel.append({"panel": _obs_numerics.step_stats(panel, fmt)})
        if j + w < n:
            v_w = _v_words(panel, fmt)
            t_w = larft(v_w, tau, fmt=fmt)
            c2 = _apply_block(a[j:, j + w:], v_w, t_w, True, gemm_backend,
                              fmt)
            a = a.at[j:, j + w:].set(c2)
            if collect:
                tel[-1]["update"] = _obs_numerics.step_stats(c2, fmt)
    return (a, taus, tel) if collect else (a, taus)


def _rormqr_body(a_qr: jax.Array, tau_p: jax.Array, c_p: jax.Array,
                 trans: bool, nb: int, gemm_backend: str,
                 fmt: PositFormat = P32E2):
    """Apply Q (trans=False) or Q^T (trans=True) from the left.

    Q = B_0 B_1 ... B_L with B_j = I - V_j T_j V_j^T, so Q^T C applies
    the transposed blocks in forward order and Q C the blocks in reverse
    (dormqr).  V and T are rebuilt from the stored words — chain values
    round-trip the encode exactly, so each block's T is bit-identical to
    the one the factorization used.
    """
    kk = tau_p.shape[0]
    c = jnp.asarray(c_p, jnp.int32)
    vec = c.ndim == 1
    if vec:
        c = c[:, None]
    starts = list(range(0, kk, nb))
    if not trans:
        starts = starts[::-1]
    for j in starts:
        w = min(nb, kk - j)
        panel = a_qr[j:, j:j + w]
        v_w = _v_words(panel, fmt)
        t_w = larft(v_w, tau_p[j:j + w], fmt=fmt)
        c2 = _apply_block(c[j:, :], v_w, t_w, trans, gemm_backend, fmt)
        c = c.at[j:, :].set(c2)
    return c[:, 0] if vec else c


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def _rgeqrf_jit(a_p: jax.Array, nb: int = 32,
                gemm_backend: str = "xla_quire",
                fmt: PositFormat = P32E2):
    return _rgeqrf_body(a_p, nb, gemm_backend, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def _rgeqrf_collect(a_p: jax.Array, nb: int, gemm_backend: str,
                    fmt: PositFormat):
    return _rgeqrf_body(a_p, nb, gemm_backend, fmt=fmt, collect=True)


def rgeqrf(a_p: jax.Array, nb: int = 32, gemm_backend: str = "xla_quire",
           fmt: PositFormat = P32E2):
    """Blocked Householder QR, ONE XLA dispatch; returns (QR, tau) with R
    on/above the diagonal and the reflector tails below it.

    With an ``obs.scoped()`` collector open and a concrete ``a_p``, the
    collect-variant program runs instead (bit-identical factors plus
    per-block-step golden-zone/regime telemetry — decomp.py contract);
    disabled or traced calls dispatch the unchanged jitted program.
    """
    if _obs_numerics.active(a_p):
        with _obs_trace.span("rgeqrf", m=int(a_p.shape[0]),
                             n=int(a_p.shape[1]), nb=nb,
                             backend=gemm_backend, fmt=fmt.name):
            qr_p, tau, tel = _rgeqrf_collect(a_p, nb=nb,
                                             gemm_backend=gemm_backend,
                                             fmt=fmt)
        _obs_numerics.emit_factor_steps("rgeqrf", tel)
        return qr_p, tau
    return _rgeqrf_jit(a_p, nb=nb, gemm_backend=gemm_backend, fmt=fmt)


def rgeqrf_loop(a_p: jax.Array, nb: int = 32,
                gemm_backend: str = "xla_quire",
                fmt: PositFormat = P32E2):
    """Dispatch-per-block Python driver over the same traced blocks —
    bit-identical to ``rgeqrf`` (the schedule changes no rounding); the
    measured baseline in benchmarks/bench_qr.py."""
    return _rgeqrf_body(jnp.asarray(a_p, jnp.int32), nb, gemm_backend,
                        fmt=fmt)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def rgeqrf_batched(a_p: jax.Array, nb: int = 32,
                   gemm_backend: str = "xla_quire",
                   fmt: PositFormat = P32E2):
    """vmapped ``rgeqrf`` over a leading (batch, m, n) axis; returns
    (QR (batch, m, n), tau (batch, min(m, n)))."""
    fn = functools.partial(_rgeqrf_body, nb=nb, gemm_backend=gemm_backend,
                           fmt=fmt)
    return jax.vmap(fn)(jnp.asarray(a_p, jnp.int32))


@functools.partial(jax.jit, static_argnames=("trans", "nb", "gemm_backend",
                                             "fmt"))
def rormqr(a_qr: jax.Array, tau_p: jax.Array, c_p: jax.Array,
           trans: bool = False, nb: int = 32,
           gemm_backend: str = "xla_quire",
           fmt: PositFormat = P32E2) -> jax.Array:
    """C <- Q C (trans=False) or Q^T C (trans=True); C may be (m,) or
    (m, nc)."""
    return _rormqr_body(jnp.asarray(a_qr, jnp.int32),
                        jnp.asarray(tau_p, jnp.int32), c_p, trans, nb,
                        gemm_backend, fmt)


@functools.partial(jax.jit, static_argnames=("ncols", "nb", "gemm_backend",
                                             "fmt"))
def rorgqr(a_qr: jax.Array, tau_p: jax.Array, ncols: int | None = None,
           nb: int = 32, gemm_backend: str = "xla_quire",
           fmt: PositFormat = P32E2) -> jax.Array:
    """Materialize the first ``ncols`` (default: all k) columns of Q by
    applying the stored reflectors to the identity (exact posit words)."""
    m = a_qr.shape[0]
    nc = tau_p.shape[0] if ncols is None else ncols
    eye = posit.from_float64(jnp.eye(m, nc, dtype=jnp.float64), fmt)
    return _rormqr_body(jnp.asarray(a_qr, jnp.int32),
                        jnp.asarray(tau_p, jnp.int32), eye, False, nb,
                        gemm_backend, fmt)


# --------------------------------------------------------------------------
# least squares
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def rgels(a_p: jax.Array, b_p: jax.Array, nb: int = 32,
          gemm_backend: str = "xla_quire", fmt: PositFormat = P32E2):
    """Over-determined least-squares solve min ||A x - b||_2 (m >= n) via
    Householder QR: x = R^{-1} (Q^T b)[:n].

    b may be (m,) or (m, nrhs).  Returns (x, (qr, tau)) — reuse the
    factors with ``rormqr`` / ``rgels_ir``'s machinery for more RHS.
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    b_p = jnp.asarray(b_p, jnp.int32)
    m, n = a_p.shape
    assert m >= n, f"rgels requires m >= n, got {a_p.shape}"
    qr_p, tau = _rgeqrf_body(a_p, nb, gemm_backend, fmt=fmt)
    c = _rormqr_body(qr_p, tau, b_p, True, nb, gemm_backend, fmt)
    r_w = _r_words(qr_p, n)
    if b_p.ndim == 1:
        x = rtrsm_left_upper(r_w, c[:n, None], unit_diag=False,
                             fmt=fmt)[:, 0]
    else:
        x = rtrsm_left_upper(r_w, c[:n, :], unit_diag=False, fmt=fmt)
    return x, (qr_p, tau)


def _snes_solve_fn(a_eq_t: jax.Array, r_w: jax.Array, inv_scale,
                   solve_fmt: PositFormat, fmt: PositFormat):
    """Correction solve for LS refinement: d = argmin ||A d - f|| by the
    semi-normal equations R^T R d = A^T f, all quire-backed:

        f_s = f / t              (power-of-two residual equilibration —
                                  exact in the f64 carrier, keeps every
                                  sweep's shrinking residual in the
                                  format's golden zone, PR-4 trick)
        w   = quire_gemv(A_eq^T, f_s)      (exact fused dot, ONE rounding)
        y   = R^T y = w;  d = R d = y      (quire-backed sweeps)
        d  <- d * t * inv_scale            (undo both equilibrations)

    ``solve_fmt`` is the factor format (== ``fmt`` for ``rgels_ir``, the
    narrow format for ``rgels_mp``); ``inv_scale`` folds the matrix
    equilibration A = s * A_eq back in (d_A = d_eq / s).
    """
    def solve_fn(f):
        fv = posit.to_float64(f, fmt)
        t = refine.pow2_scale(fv)
        f_s = posit.from_float64(fv / t, solve_fmt)
        w = quire_gemv(a_eq_t, f_s, fmt=solve_fmt)
        y = solve.rtrtrs(r_w.T, w, lower=True, quire=True, fmt=solve_fmt)
        d = solve.rtrtrs(r_w, y, lower=False, quire=True, fmt=solve_fmt)
        dv = posit.to_float64(d, solve_fmt)
        return posit.from_float64(dv * (t * inv_scale), fmt)
    return solve_fn


def _ls_driver(a_p, b_p, solve_fn, iters, fmt: PositFormat):
    """refine._driver with a rectangular residual: r = b - A (hi + lo) is
    the quire-exact LS residual (per-component fused dot, one rounding)."""
    b_p = jnp.asarray(b_p, jnp.int32)
    residual_fn = lambda hi, lo, b: refine.residual_quire(a_p, hi, b, lo,
                                                          fmt=fmt)
    one = functools.partial(refine.refine_pair, solve_fn, residual_fn,
                            iters=iters, fmt=fmt)
    if b_p.ndim == 1:
        return one(b_p)
    return jax.vmap(one, in_axes=1, out_axes=1)(b_p)


def rgels_ir(a_p: jax.Array, b_p: jax.Array, iters: int = 3, nb: int = 32,
             gemm_backend: str = "xla_quire", fmt: PositFormat = P32E2):
    """QR least squares with quire-exact iterative refinement (corrected
    semi-normal equations, Björck): factorize the power-of-two
    equilibrated A once, then Wilkinson-refine the posit-pair iterate
    with exact residuals b - A(hi+lo) and semi-normal correction solves.

    Returns ((x_hi, x_lo), (qr, tau)); the factors are of A / s.  b may
    be (m,) or (m, nrhs) (vmapped over columns); a batched (batch, m, n)
    A vmaps the whole driver.  Backward error lands on the same
    posit-pair floor as ``rgesv_ir`` (digits_lost ~ 0 across the §5.1
    sigma grid — gated in tests and benchmarks/bench_qr.py).
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rgels_ir(a, b, iters, nb, gemm_backend,
                                              fmt)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    m, n = a_p.shape
    assert m >= n, f"rgels_ir requires m >= n, got {a_p.shape}"
    av = posit.to_float64(a_p, fmt)
    s = refine.pow2_scale(av)
    a_eq = posit.from_float64(av / s, fmt)     # exact: s is a power of two
    qr_p, tau = rgeqrf(a_eq, nb=nb, gemm_backend=gemm_backend, fmt=fmt)
    solve_fn = _snes_solve_fn(a_eq.T, _r_words(qr_p, n), 1.0 / s, fmt, fmt)
    return _ls_driver(a_p, b_p, solve_fn, iters, fmt), (qr_p, tau)


def rgels_mp(a_p: jax.Array, b_p: jax.Array, iters: int = 10, nb: int = 32,
             gemm_backend: str = "xla_quire",
             factor_fmt: PositFormat = P16E1, fmt: PositFormat = P32E2):
    """Mixed-precision LS solve: Householder QR of the equilibrated A in
    ``factor_fmt`` (default Posit(16,1)), then working-format quire-exact
    refinement to the posit-pair floor.

    The narrow factorization's win here is accuracy-per-bit and (on real
    hardware) halved memory traffic; in THIS emulation QR wall-clock is
    panel-dominated and format-independent (~1.0x at dispatch-per-block
    granularity, benchmarks/bench_qr.py — unlike LU's 1.2-1.3x, whose
    trailing updates dominate).

    A, b and the returned pair are ``fmt`` words; the factors (qr, tau)
    are ``factor_fmt`` words of A / s.  Convergence: the semi-normal
    correction squares the condition number, so the contraction is
    rho ~ cond(A)^2 * eps_factor per sweep — fine for the well-
    conditioned rectangular §5.1 ensemble (cond of an (m, n) Gaussian
    ~ (sqrt(m)+sqrt(n))/(sqrt(m)-sqrt(n))), and the reason the default
    sweep count is higher than ``rgesv_mp``'s.  Same conventions
    (multi-RHS, batched A) as ``rgels_ir``.
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rgels_mp(a, b, iters, nb, gemm_backend,
                                              factor_fmt, fmt)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    m, n = a_p.shape
    assert m >= n, f"rgels_mp requires m >= n, got {a_p.shape}"
    a_lo, s = refine.mp_narrow_matrix(a_p, factor_fmt, fmt)
    qr_p, tau = rgeqrf(a_lo, nb=nb, gemm_backend=gemm_backend,
                       fmt=factor_fmt)
    solve_fn = _snes_solve_fn(a_lo.T, _r_words(qr_p, n), 1.0 / s,
                              factor_fmt, fmt)
    return _ls_driver(a_p, b_p, solve_fn, iters, fmt), (qr_p, tau)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def rgels_batched(a_p: jax.Array, b_p: jax.Array, nb: int = 32,
                  gemm_backend: str = "xla_quire",
                  fmt: PositFormat = P32E2):
    """vmapped ``rgels`` over leading (batch, m, n) / (batch, m[, nrhs])
    axes — the §5.1 ensemble / multi-scenario serving shape."""
    fn = functools.partial(rgels, nb=nb, gemm_backend=gemm_backend, fmt=fmt)
    return jax.vmap(fn)(jnp.asarray(a_p, jnp.int32),
                        jnp.asarray(b_p, jnp.int32))


# --------------------------------------------------------------------------
# binary32 baseline (the §5.1 comparison column)
# --------------------------------------------------------------------------

def sgels(a32: jax.Array, b32: jax.Array) -> jax.Array:
    """binary32 least squares via XLA QR — the S-prefixed baseline."""
    q, r = jnp.linalg.qr(a32.astype(jnp.float32))
    return jax.scipy.linalg.solve_triangular(r, q.T @ b32.astype(jnp.float32),
                                             lower=False)


# --------------------------------------------------------------------------
# checksum-protected driver (exact ABFT, repro.ft — DESIGN.md §11)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("j", "nb", "gemm_backend",
                                             "fmt"))
def _rgeqrf_ft_step(a, taus, *, j, nb, gemm_backend, fmt):
    """One rgeqrf block step (the _rgeqrf_body per-j ops) + checksum
    production over both the matrix and the tau vector, one dispatch.
    Injection and verification run on the host, so the compiled step is
    fault-plan-independent (decomp.py _ft convention)."""
    from repro.ft import abft
    m, n = a.shape
    kk = min(m, n)
    w = min(nb, kk - j)
    panel, tau = geqr2(a[j:, j:j + w], fmt=fmt)
    a = a.at[j:, j:j + w].set(panel)
    taus = taus.at[j:j + w].set(tau)
    if j + w < n:
        v_w = _v_words(panel, fmt)
        t_w = larft(v_w, tau, fmt=fmt)
        c2 = _apply_block(a[j:, j + w:], v_w, t_w, True, gemm_backend, fmt)
        a = a.at[j:, j + w:].set(c2)
    return a, taus, abft.checksum(a, fmt), abft.checksum(taus[None, :], fmt)


def rgeqrf_ft(a_p: jax.Array, nb: int = 32, gemm_backend: str = "xla_quire",
              fmt: PositFormat = P32E2, plan=None, max_retries: int = 2):
    """Checksum-protected blocked Householder QR: returns
    (QR, tau, FtReport) — bit-identical to ``rgeqrf`` fault-free and
    after recovery (repro.ft exact-ABFT contract: total threshold-free
    detection, retry from the verified predecessor state, ``AbftError``
    past ``max_retries``).  Injection sites: ``"rgeqrf.step"`` (matrix
    words) and ``"rgeqrf.tau"`` (reflector scalars), step = j // nb,
    first attempt only."""
    from repro import ft
    m, n = a_p.shape
    kk = min(m, n)
    a = jnp.asarray(a_p, jnp.int32)
    taus = jnp.zeros((kk,), jnp.int32)
    report = ft.FtReport()
    for j in range(0, kk, nb):
        a_prev, taus_prev = a, taus
        for attempt in range(max_retries + 1):
            a, taus, cks, cks_t = _rgeqrf_ft_step(
                a_prev, taus_prev, j=j, nb=nb, gemm_backend=gemm_backend,
                fmt=fmt)
            if attempt == 0 and plan is not None:
                a = plan.words("rgeqrf.step", j // nb, a, fmt)
                taus = plan.words("rgeqrf.tau", j // nb, taus, fmt)
            ok_a, bad_row, bad_col = ft.abft._verify_jit(a, cks, fmt=fmt)
            ok_t, _, _ = ft.abft._verify_jit(taus[None, :], cks_t, fmt=fmt)
            ok = ok_a & ok_t
            if bool(ok):
                report.retries += attempt
                break
            report.detections += 1
            report.sites.append(("rgeqrf.step", j // nb,
                                 ft.locate(bad_row, bad_col, nb)))
            _obs_metrics.inc("ft.detections")
            _obs_metrics.inc("ft.retries")
        else:
            report.failed = True
            raise ft.abft.AbftError(
                f"rgeqrf_ft: step {j // nb} mismatch persisted across "
                f"{max_retries + 1} attempts at {report.sites}")
    return a, taus, report
