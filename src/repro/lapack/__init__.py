"""MPLAPACK-style posit linear algebra (paper §3/§5).

Routines carry MPLAPACK's ``R`` prefix: Rgemm (kernels/ops.py), Rtrsm,
Rpotrf/Rpotrs (Cholesky), Rgetrf/Rgetrs (LU with partial pivoting),
Rgeqrf/Rormqr/Rorgqr/Rgels (Householder QR and least squares), plus
binary32 baselines (S-prefix) and the paper's backward-error protocol.
"""
from repro.lapack.blas import (rlarfg_chain, rtrsm_left_lower,
                               rtrsm_left_upper, rtrsm_right_lowerT,
                               rtrsv_lower, rtrsv_lower_quire, rtrsv_upper,
                               rtrsv_upper_quire)
from repro.lapack.decomp import (rpotrf, rpotrf_batched, rpotrf_loop, rgetrf,
                                 rgetrf_batched, rgetrf_loop, spotrf, sgetrf)
from repro.lapack.solve import rpotrs, rgetrs, rtrtrs, spotrs, sgetrs
from repro.lapack.refine import (mp_narrow_matrix, pair_to_float64,
                                 pow2_scale, refine_pair, rgesv_ir,
                                 rgesv_mp, rposv_ir, rposv_mp,
                                 residual_quire)
from repro.lapack.qr import (rgels, rgels_batched, rgels_ir, rgels_mp,
                             rgeqrf, rgeqrf_batched, rgeqrf_loop, rorgqr,
                             rormqr, sgels)
from repro.lapack.error_eval import (backward_error_ensemble,
                                     backward_error_study,
                                     least_squares_study, make_spd,
                                     make_general, mixed_precision_study,
                                     refinement_study)

__all__ = [
    "rtrsm_left_lower", "rtrsm_left_upper", "rtrsm_right_lowerT",
    "rtrsv_lower", "rtrsv_upper",
    "rtrsv_lower_quire", "rtrsv_upper_quire", "rlarfg_chain",
    "rpotrf", "rpotrf_batched", "rpotrf_loop",
    "rgetrf", "rgetrf_batched", "rgetrf_loop", "spotrf", "sgetrf",
    "rgeqrf", "rgeqrf_batched", "rgeqrf_loop", "rormqr", "rorgqr",
    "rgels", "rgels_batched", "rgels_ir", "rgels_mp", "sgels",
    "backward_error_ensemble",
    "rpotrs", "rgetrs", "rtrtrs", "spotrs", "sgetrs",
    "rgesv_ir", "rposv_ir", "rgesv_mp", "rposv_mp",
    "residual_quire", "refine_pair", "pair_to_float64",
    "pow2_scale", "mp_narrow_matrix",
    "backward_error_study", "least_squares_study", "make_spd",
    "make_general", "refinement_study", "mixed_precision_study",
]
