"""MPLAPACK-style posit linear algebra (paper §3/§5).

Routines carry MPLAPACK's ``R`` prefix: Rgemm (kernels/ops.py), Rtrsm,
Rpotrf/Rpotrs (Cholesky), Rgetrf/Rgetrs (LU with partial pivoting), plus
binary32 baselines (S-prefix) and the paper's backward-error protocol.
"""
from repro.lapack.blas import rtrsm_left_lower, rtrsm_right_lowerT, rtrsv_lower, rtrsv_upper
from repro.lapack.decomp import rpotrf, rgetrf, spotrf, sgetrf
from repro.lapack.solve import rpotrs, rgetrs, spotrs, sgetrs
from repro.lapack.error_eval import backward_error_study, make_spd, make_general

__all__ = [
    "rtrsm_left_lower", "rtrsm_right_lowerT", "rtrsv_lower", "rtrsv_upper",
    "rpotrf", "rgetrf", "spotrf", "sgetrf",
    "rpotrs", "rgetrs", "spotrs", "sgetrs",
    "backward_error_study", "make_spd", "make_general",
]
