"""Blocked Cholesky (Rpotrf) and LU (Rgetrf) in posit arithmetic.

Right-looking LAPACK algorithms (dpotrf/dgetrf, Toledo [30]): unblocked
panel factorizations run fully in posit arithmetic (every scalar op
rounded), and the trailing-matrix update is a single Rgemm call — exactly
the paper's offload split ("Both Rpotrf and Rgetrf call Rgemm for updating
the trailing matrix", §5.2).  ``gemm_backend`` selects the accelerator
semantics: 'faithful' (paper's per-MAC-rounding PE), 'xla_quire'
(beyond-paper tile accumulation), 'quire_exact' (true posit-standard
quire — the alpha=-1/beta=1 trailing updates here are single-rounding
fused ops, see repro.quire), or 'pallas_split3[_comp]' (the TPU kernel
in interpret mode).

``fmt`` selects the posit format (static, default Posit(32,2)): the SAME
traced program factorizes in any registered format — this is what the
mixed-precision solvers (lapack/refine.py rgesv_mp/rposv_mp) build on,
factorizing cheap in p16e1 and refining exact in p32e2 (DESIGN.md §8).

Execution model (DESIGN.md §6.2): the block schedule is **static at trace
time**, so ``rpotrf``/``rgetrf`` are single-dispatch — the whole blocked
factorization (panels + triangular solves + trailing Rgemms) is ONE jitted
XLA program instead of ~n/nb Python-level dispatches with full-matrix
``at[].set`` copies between them.  The pre-PR-2 Python-loop drivers are
kept as ``rpotrf_loop``/``rgetrf_loop`` (bit-identical — same traced ops,
different dispatch granularity) as the measured baseline for
``benchmarks/bench_decomp.py``.  ``rpotrf_batched``/``rgetrf_batched``
vmap the same program over a leading matrix axis — the paper's §5.1
ensemble protocol (many matrices x many phi scales) as one batched
program.

Panel kernels run in fused-chain form (core/posit.py): operands decode to
f64 once at panel entry, every scalar op is still individually rounded to
the posit lattice (``chain_round``), and words are encoded once at panel
exit — bit-identical to per-op fast-backend words, minus the redundant
decode/encode round-trips.

binary32 baselines (spotrf/sgetrf) use the same XLA algorithms in f32,
standing in for LAPACK's spotrf/sgetrf as in the paper's comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P32E2, PositFormat
from repro.kernels.ops import rgemm
from repro.lapack.blas import rtrsm_left_lower, rtrsm_right_lowerT
from repro.obs import metrics as _obs_metrics
from repro.obs import numerics as _obs_numerics
from repro.obs import trace as _obs_trace

_FMT = P32E2


# --------------------------------------------------------------------------
# unblocked panel kernels (all-posit, fused-chain form)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("fmt",))
def potf2(a_p: jax.Array, fmt: PositFormat = P32E2) -> jax.Array:
    """Unblocked lower Cholesky of an (n,n) posit matrix, dpotf2 op order.

    Decode-once / encode-once: the panel enters f64 once, every scalar op
    is posit-rounded in place (chain_round), words are packed once at exit.
    """
    n = a_p.shape[0]
    rows = jnp.arange(n)
    a = posit.chain_decode(a_p, fmt)

    def outer(a, j):
        # col <- A[:, j] - A[:, :j] @ A[j, :j]   (chained over k < j)
        def inner(col, k):
            upd = posit.chain_sub(col, posit.chain_mul(a[:, k], a[j, k],
                                                       fmt), fmt)
            return jnp.where(k < j, upd, col), None

        col, _ = jax.lax.scan(inner, a[:, j], jnp.arange(n))
        ajj = posit.chain_sqrt(col[j], fmt)
        below = posit.chain_div(col, ajj, fmt)
        newcol = jnp.where(rows > j, below, jnp.where(rows == j, ajj, a[:, j]))
        return a.at[:, j].set(newcol), None

    a, _ = jax.lax.scan(outer, a, jnp.arange(n))
    return posit.chain_encode(a, fmt)


@functools.partial(jax.jit, static_argnames=("nb", "fmt"))
def getf2(a_p: jax.Array, nb: int, fmt: PositFormat = P32E2):
    """Unblocked partial-pivot LU of an (m, nb) posit panel (dgetf2 order).

    Returns (panel, ipiv) with L strictly-below-diagonal (unit diag) and U
    on/above.  Pivot search compares |value| — decoded posit values order
    exactly like the word patterns (posits are monotone), so the f64
    comparison picks the same pivot the word comparison did.  Fused-chain
    execution: decode once, per-op rounding in f64, encode once.
    """
    m = a_p.shape[0]
    rows = jnp.arange(m)
    a0 = posit.chain_decode(a_p, fmt)

    def step(a, k):
        col = jnp.where(rows >= k, jnp.abs(a[:, k]), -1.0)
        col = jnp.where(jnp.isnan(col), -1.0, col)       # NaR never pivots
        piv = jnp.argmax(col).astype(jnp.int32)
        rk, rp = a[k, :], a[piv, :]
        a = a.at[k, :].set(rp).at[piv, :].set(rk)
        scaled = posit.chain_div(a[:, k], a[k, k], fmt)
        a = a.at[:, k].set(jnp.where(rows > k, scaled, a[:, k]))
        upd = posit.chain_sub(a, posit.chain_mul(a[:, k][:, None],
                                                 a[k, :][None, :], fmt), fmt)
        mask = (rows > k)[:, None] & (jnp.arange(a.shape[1]) > k)[None, :]
        a = jnp.where(mask, upd, a)
        return a, piv

    a, ipiv = jax.lax.scan(step, a0, jnp.arange(nb))
    return posit.chain_encode(a, fmt), ipiv


# --------------------------------------------------------------------------
# legacy word-domain panels — the pre-PR-2 implementations, kept as the
# measured baseline for the loop drivers (bit-identical to the chain
# panels; every intermediate round-trips through a posit word)
# --------------------------------------------------------------------------

def _mul(a, b, fmt=_FMT):
    return posit.mul(a, b, fmt, backend="fast")


def _sub(a, b, fmt=_FMT):
    return posit.sub(a, b, fmt, backend="fast")


def _div(a, b, fmt=_FMT):
    return posit.div(a, b, fmt, backend="fast")


@functools.partial(jax.jit, static_argnames=("fmt",))
def _potf2_words(a_p: jax.Array, fmt: PositFormat = P32E2) -> jax.Array:
    """Pre-PR-2 potf2: per-op decode/encode through posit words."""
    n = a_p.shape[0]
    rows = jnp.arange(n)

    def outer(a, j):
        def inner(col, k):
            upd = _sub(col, _mul(a[:, k], a[j, k], fmt), fmt)
            return jnp.where(k < j, upd, col), None

        col, _ = jax.lax.scan(inner, a[:, j], jnp.arange(n))
        ajj = posit.sqrt(col[j], fmt, backend="fast")
        below = _div(col, ajj, fmt)
        newcol = jnp.where(rows > j, below, jnp.where(rows == j, ajj, a[:, j]))
        return a.at[:, j].set(newcol), None

    a, _ = jax.lax.scan(outer, a_p, jnp.arange(n))
    return a


@functools.partial(jax.jit, static_argnames=("nb", "fmt"))
def _getf2_words(a_p: jax.Array, nb: int, fmt: PositFormat = P32E2):
    """Pre-PR-2 getf2: per-op decode/encode, word-pattern pivot compare."""
    m = a_p.shape[0]
    rows = jnp.arange(m)

    def step(a, k):
        col = jnp.where(rows >= k, jnp.abs(a[:, k]), -1)
        piv = jnp.argmax(col).astype(jnp.int32)
        rk, rp = a[k, :], a[piv, :]
        a = a.at[k, :].set(rp).at[piv, :].set(rk)
        scaled = _div(a[:, k], a[k, k], fmt)
        a = a.at[:, k].set(jnp.where(rows > k, scaled, a[:, k]))
        upd = _sub(a, _mul(a[:, k][:, None], a[k, :][None, :], fmt), fmt)
        mask = (rows > k)[:, None] & (jnp.arange(a.shape[1]) > k)[None, :]
        a = jnp.where(mask, upd, a)
        return a, piv

    a, ipiv = jax.lax.scan(step, a_p, jnp.arange(nb))
    return a, ipiv


# --------------------------------------------------------------------------
# blocked drivers — one traced body, three dispatch shapes
# --------------------------------------------------------------------------

def _rpotrf_body(a_p: jax.Array, nb: int, gemm_backend: str,
                 panel=potf2, fmt: PositFormat = P32E2,
                 collect: bool = False):
    """Right-looking blocked Cholesky; block schedule unrolled at trace.

    ``collect=True`` (the obs-variant program, a SEPARATE jit cache entry
    — see ``rpotrf``) additionally returns a per-block-step telemetry
    list: golden-zone occupancy / regime stats of each factored panel and
    trailing update (repro.obs.numerics.step_stats)."""
    n = a_p.shape[0]
    a = jnp.asarray(a_p, jnp.int32)
    tel = []
    for j in range(0, n, nb):
        w = min(nb, n - j)
        l11 = panel(a[j:j + w, j:j + w], fmt=fmt)
        a = a.at[j:j + w, j:j + w].set(l11)
        step = {"panel": _obs_numerics.step_stats(l11, fmt)} if collect \
            else None
        if j + w < n:
            a21 = rtrsm_right_lowerT(a[j + w:, j:j + w], l11, fmt=fmt)
            a = a.at[j + w:, j:j + w].set(a21)
            upd = rgemm(a21, a21, a[j + w:, j + w:], alpha=-1.0, beta=1.0,
                        trans_b=True, backend=gemm_backend, fmt=fmt)
            a = a.at[j + w:, j + w:].set(upd)
            if collect:
                step["update"] = _obs_numerics.step_stats(upd, fmt)
        if collect:
            tel.append(step)
    # zero strict upper triangle (posit word 0 == value 0)
    tri = jnp.tril(jnp.ones((n, n), bool))
    out = jnp.where(tri, a, 0)
    return (out, tel) if collect else out


def _rgetrf_body(a_p: jax.Array, nb: int, gemm_backend: str,
                 panel_fn=getf2, fmt: PositFormat = P32E2,
                 collect: bool = False):
    """Right-looking blocked partial-pivot LU; schedule unrolled at trace.
    ``collect=True`` adds the per-step telemetry list (see
    ``_rpotrf_body``)."""
    n = a_p.shape[1]
    m = a_p.shape[0]
    a = jnp.asarray(a_p, jnp.int32)
    ipiv = jnp.zeros((min(m, n),), jnp.int32)
    tel = []
    for j in range(0, min(m, n), nb):
        w = min(nb, min(m, n) - j)
        panel, piv_loc = panel_fn(a[j:, j:j + w], w, fmt=fmt)
        if collect:
            tel.append({"panel": _obs_numerics.step_stats(panel, fmt)})
        # apply the panel's row swaps to the rest of the matrix
        left = a[j:, :j]
        right = a[j:, j + w:]

        def apply_swaps(blk):
            def one(b, kp):
                k, p = kp
                rk, rp = b[k, :], b[p, :]
                return b.at[k, :].set(rp).at[p, :].set(rk), None
            blk, _ = jax.lax.scan(one, blk, (jnp.arange(w), piv_loc))
            return blk

        if j > 0:
            left = apply_swaps(left)
            a = a.at[j:, :j].set(left)
        if j + w < n:
            right = apply_swaps(right)
        a = a.at[j:, j:j + w].set(panel)
        ipiv = ipiv.at[j:j + w].set(piv_loc + j)
        if j + w < n:
            u12 = rtrsm_left_lower(panel[:w, :], right[:w, :], unit_diag=True,
                                   fmt=fmt)
            a = a.at[j:j + w, j + w:].set(u12)
            if j + w < m:
                l21 = panel[w:, :]
                upd = rgemm(l21, u12, right[w:, :], alpha=-1.0, beta=1.0,
                            backend=gemm_backend, fmt=fmt)
                a = a.at[j + w:, j + w:].set(upd)
                if collect:
                    tel[-1]["update"] = _obs_numerics.step_stats(upd, fmt)
    return (a, ipiv, tel) if collect else (a, ipiv)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def _rpotrf_jit(a_p: jax.Array, nb: int = 64,
                gemm_backend: str = "xla_quire",
                fmt: PositFormat = P32E2) -> jax.Array:
    return _rpotrf_body(a_p, nb, gemm_backend, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def _rgetrf_jit(a_p: jax.Array, nb: int = 64,
                gemm_backend: str = "xla_quire",
                fmt: PositFormat = P32E2):
    return _rgetrf_body(a_p, nb, gemm_backend, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def _rpotrf_collect(a_p: jax.Array, nb: int, gemm_backend: str,
                    fmt: PositFormat):
    return _rpotrf_body(a_p, nb, gemm_backend, fmt=fmt, collect=True)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def _rgetrf_collect(a_p: jax.Array, nb: int, gemm_backend: str,
                    fmt: PositFormat):
    return _rgetrf_body(a_p, nb, gemm_backend, fmt=fmt, collect=True)


def rpotrf(a_p: jax.Array, nb: int = 64, gemm_backend: str = "xla_quire",
           fmt: PositFormat = P32E2) -> jax.Array:
    """Blocked lower Cholesky, ONE XLA dispatch; returns L (lower).

    With an ``obs.scoped()`` collector open (and a concrete ``a_p``),
    runs the collect-variant program instead — same factorization ops
    plus per-block-step golden-zone/regime telemetry (bit-identical L,
    separate jit cache entry); otherwise dispatches the exact program
    this function has always been.
    """
    if _obs_numerics.active(a_p):
        with _obs_trace.span("rpotrf", n=int(a_p.shape[0]), nb=nb,
                             backend=gemm_backend, fmt=fmt.name):
            out, tel = _rpotrf_collect(a_p, nb=nb,
                                       gemm_backend=gemm_backend, fmt=fmt)
        _obs_numerics.emit_factor_steps("rpotrf", tel)
        return out
    return _rpotrf_jit(a_p, nb=nb, gemm_backend=gemm_backend, fmt=fmt)


def rgetrf(a_p: jax.Array, nb: int = 64, gemm_backend: str = "xla_quire",
           fmt: PositFormat = P32E2):
    """Blocked partial-pivot LU, ONE XLA dispatch; returns (LU, ipiv).
    Observability contract as in ``rpotrf``."""
    if _obs_numerics.active(a_p):
        with _obs_trace.span("rgetrf", m=int(a_p.shape[0]),
                             n=int(a_p.shape[1]), nb=nb,
                             backend=gemm_backend, fmt=fmt.name):
            lu, ipiv, tel = _rgetrf_collect(a_p, nb=nb,
                                            gemm_backend=gemm_backend,
                                            fmt=fmt)
        _obs_numerics.emit_factor_steps("rgetrf", tel)
        return lu, ipiv
    return _rgetrf_jit(a_p, nb=nb, gemm_backend=gemm_backend, fmt=fmt)


def rpotrf_loop(a_p: jax.Array, nb: int = 64,
                gemm_backend: str = "xla_quire",
                fmt: PositFormat = P32E2) -> jax.Array:
    """The pre-PR-2 dispatch shape: dispatch-per-block Python driver over
    the word-domain panels.  The trsm sweeps are the shared (chain-form)
    implementations — the original word-domain trsm was not kept — so
    this baseline is slightly FASTER than the true pre-PR-2 code and the
    benchmark's reported speedups are conservative.  Bit-identical to
    ``rpotrf`` (no schedule change alters rounding); the measured
    baseline in benchmarks/bench_decomp.py."""
    return _rpotrf_body(a_p, nb, gemm_backend, panel=_potf2_words, fmt=fmt)


def rgetrf_loop(a_p: jax.Array, nb: int = 64,
                gemm_backend: str = "xla_quire",
                fmt: PositFormat = P32E2):
    """Pre-PR-2 dispatch-per-block driver (bit-identical to ``rgetrf``;
    same conservative-baseline caveat as ``rpotrf_loop``)."""
    return _rgetrf_body(a_p, nb, gemm_backend, panel_fn=_getf2_words, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def rpotrf_batched(a_p: jax.Array, nb: int = 64,
                   gemm_backend: str = "xla_quire",
                   fmt: PositFormat = P32E2) -> jax.Array:
    """vmapped ``rpotrf`` over a leading (batch, n, n) axis — the §5.1
    ensemble / multi-scenario serving shape as one batched program."""
    fn = functools.partial(_rpotrf_body, nb=nb, gemm_backend=gemm_backend,
                           fmt=fmt)
    return jax.vmap(fn)(jnp.asarray(a_p, jnp.int32))


@functools.partial(jax.jit, static_argnames=("nb", "gemm_backend", "fmt"))
def rgetrf_batched(a_p: jax.Array, nb: int = 64,
                   gemm_backend: str = "xla_quire",
                   fmt: PositFormat = P32E2):
    """vmapped ``rgetrf`` over a leading (batch, m, n) axis; returns
    (LU (batch, m, n), ipiv (batch, min(m, n)))."""
    fn = functools.partial(_rgetrf_body, nb=nb, gemm_backend=gemm_backend,
                           fmt=fmt)
    return jax.vmap(fn)(jnp.asarray(a_p, jnp.int32))


# --------------------------------------------------------------------------
# binary32 baselines
# --------------------------------------------------------------------------

def spotrf(a32: jax.Array) -> jax.Array:
    return jax.scipy.linalg.cholesky(a32.astype(jnp.float32), lower=True)


def sgetrf(a32: jax.Array):
    lu, piv = jax.scipy.linalg.lu_factor(a32.astype(jnp.float32))
    return lu, piv


# --------------------------------------------------------------------------
# checksum-protected drivers (exact ABFT, repro.ft — DESIGN.md §11)
# --------------------------------------------------------------------------
#
# The _ft drivers re-state the SAME per-block-step ops as
# _rpotrf_body/_rgetrf_body — duplicated, not refactored, so the frozen
# _rpotrf_jit/_rgetrf_jit programs (and their lowered HLO) are untouched
# — but host-stepped: each block step is one jitted dispatch that ends
# with full-matrix checksum production, the fault-injection window, and
# verification.  A mismatch means some stored word changed between this
# step's production and its verification; the host retries the step from
# its verified predecessor state (the arrays are functional values, so
# recomputation fully repairs any corruption), bounded by max_retries.
# Fault-free, the words are bit-identical to the unprotected drivers:
# same ops, same order, same backends, and the checksum legs only read.

def _ft():
    # deferred import: keeps repro.lapack importable without pulling the
    # ft package into modules that never use protection
    from repro import ft as _pkg
    return _pkg


@functools.partial(jax.jit, static_argnames=("j", "nb", "gemm_backend",
                                             "fmt"))
def _rpotrf_ft_step(a, *, j, nb, gemm_backend, fmt):
    """One rpotrf block step (the _rpotrf_body per-j ops) + checksum
    production, one dispatch.  The injection window and verify leg run
    on the host so the compiled step is fault-plan-independent."""
    from repro.ft import abft
    n = a.shape[0]
    w = min(nb, n - j)
    l11 = potf2(a[j:j + w, j:j + w], fmt=fmt)
    a = a.at[j:j + w, j:j + w].set(l11)
    if j + w < n:
        a21 = rtrsm_right_lowerT(a[j + w:, j:j + w], l11, fmt=fmt)
        a = a.at[j + w:, j:j + w].set(a21)
        upd = rgemm(a21, a21, a[j + w:, j + w:], alpha=-1.0, beta=1.0,
                    trans_b=True, backend=gemm_backend, fmt=fmt)
        a = a.at[j + w:, j + w:].set(upd)
    return a, abft.checksum(a, fmt)


def rpotrf_ft(a_p: jax.Array, nb: int = 64, gemm_backend: str = "xla_quire",
              fmt: PositFormat = P32E2, plan=None, max_retries: int = 2):
    """Checksum-protected blocked Cholesky: returns (L, FtReport).

    Detection is total and threshold-free (exact quire-limb checksums —
    see repro.ft.abft); a corrupted step recomputes from its verified
    predecessor, so the recovered L is bit-identical to the fault-free
    ``rpotrf``.  Exhausting ``max_retries`` on one step raises
    ``AbftError``.  Injection site: ``"rpotrf.step"`` (step = j // nb),
    applied on the first attempt only (transient-fault model)."""
    ft = _ft()
    n = a_p.shape[0]
    a = jnp.asarray(a_p, jnp.int32)
    report = ft.FtReport()
    for j in range(0, n, nb):
        a_prev = a
        for attempt in range(max_retries + 1):
            a, cks = _rpotrf_ft_step(a_prev, j=j, nb=nb,
                                     gemm_backend=gemm_backend, fmt=fmt)
            if attempt == 0 and plan is not None:
                a = plan.words("rpotrf.step", j // nb, a, fmt)
            ok, bad_row, bad_col = ft.abft._verify_jit(a, cks, fmt=fmt)
            if bool(ok):
                report.retries += attempt
                break
            report.detections += 1
            report.sites.append(("rpotrf.step", j // nb,
                                 ft.locate(bad_row, bad_col, nb)))
            _obs_metrics.inc("ft.detections")
            _obs_metrics.inc("ft.retries")
        else:
            report.failed = True
            raise ft.abft.AbftError(
                f"rpotrf_ft: step {j // nb} mismatch persisted across "
                f"{max_retries + 1} attempts at {report.sites}")
    tri = jnp.tril(jnp.ones((n, n), bool))
    return jnp.where(tri, a, 0), report


@functools.partial(jax.jit, static_argnames=("j", "nb", "gemm_backend",
                                             "fmt"))
def _rgetrf_ft_step(a, ipiv, *, j, nb, gemm_backend, fmt):
    """One rgetrf block step (the _rgetrf_body per-j ops) + checksum
    production (fault-plan-independent program; injection and verify run
    on the host, see _rpotrf_ft_step)."""
    from repro.ft import abft
    m, n = a.shape
    w = min(nb, min(m, n) - j)
    panel, piv_loc = getf2(a[j:, j:j + w], w, fmt=fmt)
    left = a[j:, :j]
    right = a[j:, j + w:]

    def apply_swaps(blk):
        def one(b, kp):
            k, p = kp
            rk, rp = b[k, :], b[p, :]
            return b.at[k, :].set(rp).at[p, :].set(rk), None
        blk, _ = jax.lax.scan(one, blk, (jnp.arange(w), piv_loc))
        return blk

    if j > 0:
        left = apply_swaps(left)
        a = a.at[j:, :j].set(left)
    if j + w < n:
        right = apply_swaps(right)
    a = a.at[j:, j:j + w].set(panel)
    ipiv = ipiv.at[j:j + w].set(piv_loc + j)
    if j + w < n:
        u12 = rtrsm_left_lower(panel[:w, :], right[:w, :], unit_diag=True,
                               fmt=fmt)
        a = a.at[j:j + w, j + w:].set(u12)
        if j + w < m:
            l21 = panel[w:, :]
            upd = rgemm(l21, u12, right[w:, :], alpha=-1.0, beta=1.0,
                        backend=gemm_backend, fmt=fmt)
            a = a.at[j + w:, j + w:].set(upd)
    return a, ipiv, abft.checksum(a, fmt)


def rgetrf_ft(a_p: jax.Array, nb: int = 64, gemm_backend: str = "xla_quire",
              fmt: PositFormat = P32E2, plan=None, max_retries: int = 2):
    """Checksum-protected blocked partial-pivot LU: returns
    (LU, ipiv, FtReport) — (LU, ipiv) bit-identical to ``rgetrf`` both
    fault-free and after recovery.  Contract and injection model as in
    ``rpotrf_ft``; site ``"rgetrf.step"``."""
    ft = _ft()
    m, n = a_p.shape
    a = jnp.asarray(a_p, jnp.int32)
    ipiv = jnp.zeros((min(m, n),), jnp.int32)
    report = ft.FtReport()
    for j in range(0, min(m, n), nb):
        a_prev, ipiv_prev = a, ipiv
        for attempt in range(max_retries + 1):
            a, ipiv, cks = _rgetrf_ft_step(
                a_prev, ipiv_prev, j=j, nb=nb, gemm_backend=gemm_backend,
                fmt=fmt)
            if attempt == 0 and plan is not None:
                a = plan.words("rgetrf.step", j // nb, a, fmt)
            ok, bad_row, bad_col = ft.abft._verify_jit(a, cks, fmt=fmt)
            if bool(ok):
                report.retries += attempt
                break
            report.detections += 1
            report.sites.append(("rgetrf.step", j // nb,
                                 ft.locate(bad_row, bad_col, nb)))
            _obs_metrics.inc("ft.detections")
            _obs_metrics.inc("ft.retries")
        else:
            report.failed = True
            raise ft.abft.AbftError(
                f"rgetrf_ft: step {j // nb} mismatch persisted across "
                f"{max_retries + 1} attempts at {report.sites}")
    return a, ipiv, report
