"""Blocked Cholesky (Rpotrf) and LU (Rgetrf) in Posit(32,2) arithmetic.

Right-looking LAPACK algorithms (dpotrf/dgetrf, Toledo [30]): unblocked
panel factorizations run fully in posit arithmetic (every scalar op
rounded), and the trailing-matrix update is a single Rgemm call — exactly
the paper's offload split ("Both Rpotrf and Rgetrf call Rgemm for updating
the trailing matrix", §5.2).  ``gemm_backend`` selects the accelerator
semantics: 'faithful' (paper's per-MAC-rounding PE), 'xla_quire'
(beyond-paper tile accumulation), 'quire_exact' (true posit-standard
quire — the alpha=-1/beta=1 trailing updates here are single-rounding
fused ops, see repro.quire), or 'pallas_split3[_comp]' (the TPU kernel
in interpret mode).

binary32 baselines (spotrf/sgetrf) use the same XLA algorithms in f32,
standing in for LAPACK's spotrf/sgetrf as in the paper's comparison.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P32E2
from repro.kernels.ops import rgemm
from repro.lapack.blas import rtrsm_left_lower, rtrsm_right_lowerT

_FMT = P32E2


def _mul(a, b):
    return posit.mul(a, b, _FMT, backend="fast")


def _sub(a, b):
    return posit.sub(a, b, _FMT, backend="fast")


def _div(a, b):
    return posit.div(a, b, _FMT, backend="fast")


# --------------------------------------------------------------------------
# unblocked panel kernels (all-posit)
# --------------------------------------------------------------------------

@jax.jit
def potf2(a_p: jax.Array) -> jax.Array:
    """Unblocked lower Cholesky of an (n,n) posit matrix, dpotf2 op order."""
    n = a_p.shape[0]
    rows = jnp.arange(n)

    def outer(a, j):
        # col <- A[:, j] - A[:, :j] @ A[j, :j]   (chained over k < j)
        def inner(col, k):
            upd = _sub(col, _mul(a[:, k], a[j, k]))
            return jnp.where(k < j, upd, col), None

        col, _ = jax.lax.scan(inner, a[:, j], jnp.arange(n))
        ajj = posit.sqrt(col[j], _FMT, backend="fast")
        below = _div(col, ajj)
        newcol = jnp.where(rows > j, below, jnp.where(rows == j, ajj, a[:, j]))
        return a.at[:, j].set(newcol), None

    a, _ = jax.lax.scan(outer, a_p, jnp.arange(n))
    return a


@functools.partial(jax.jit, static_argnames=("nb",))
def getf2(a_p: jax.Array, nb: int):
    """Unblocked partial-pivot LU of an (m, nb) posit panel (dgetf2 order).

    Returns (panel, ipiv) with L strictly-below-diagonal (unit diag) and U
    on/above.  Pivot search compares |value| via |pattern| — posit
    patterns are monotone in value, so integer abs order IS value order.
    """
    m = a_p.shape[0]
    rows = jnp.arange(m)

    def step(a, k):
        col = jnp.where(rows >= k, jnp.abs(a[:, k]), -1)
        piv = jnp.argmax(col).astype(jnp.int32)
        rk, rp = a[k, :], a[piv, :]
        a = a.at[k, :].set(rp).at[piv, :].set(rk)
        scaled = _div(a[:, k], a[k, k])
        a = a.at[:, k].set(jnp.where(rows > k, scaled, a[:, k]))
        upd = _sub(a, _mul(a[:, k][:, None], a[k, :][None, :]))
        mask = (rows > k)[:, None] & (jnp.arange(a.shape[1]) > k)[None, :]
        a = jnp.where(mask, upd, a)
        return a, piv

    a, ipiv = jax.lax.scan(step, a_p, jnp.arange(nb))
    return a, ipiv


# --------------------------------------------------------------------------
# blocked drivers
# --------------------------------------------------------------------------

def rpotrf(a_p: jax.Array, nb: int = 64, gemm_backend: str = "xla_quire"
           ) -> jax.Array:
    """Blocked lower Cholesky; returns L in the lower triangle."""
    n = a_p.shape[0]
    a = jnp.asarray(a_p, jnp.int32)
    for j in range(0, n, nb):
        w = min(nb, n - j)
        l11 = potf2(a[j:j + w, j:j + w])
        a = a.at[j:j + w, j:j + w].set(l11)
        if j + w < n:
            a21 = rtrsm_right_lowerT(a[j + w:, j:j + w], l11)
            a = a.at[j + w:, j:j + w].set(a21)
            upd = rgemm(a21, a21, a[j + w:, j + w:], alpha=-1.0, beta=1.0,
                        trans_b=True, backend=gemm_backend)
            a = a.at[j + w:, j + w:].set(upd)
    # zero strict upper triangle (posit word 0 == value 0)
    tri = jnp.tril(jnp.ones((n, n), bool))
    return jnp.where(tri, a, 0)


def rgetrf(a_p: jax.Array, nb: int = 64, gemm_backend: str = "xla_quire"):
    """Blocked partial-pivot LU; returns (LU, ipiv) like dgetrf."""
    n = a_p.shape[1]
    m = a_p.shape[0]
    a = jnp.asarray(a_p, jnp.int32)
    ipiv = jnp.zeros((min(m, n),), jnp.int32)
    for j in range(0, min(m, n), nb):
        w = min(nb, min(m, n) - j)
        panel, piv_loc = getf2(a[j:, j:j + w], w)
        # apply the panel's row swaps to the rest of the matrix
        left = a[j:, :j]
        right = a[j:, j + w:]

        def apply_swaps(blk):
            def one(b, kp):
                k, p = kp
                rk, rp = b[k, :], b[p, :]
                return b.at[k, :].set(rp).at[p, :].set(rk), None
            blk, _ = jax.lax.scan(one, blk, (jnp.arange(w), piv_loc))
            return blk

        if j > 0:
            left = apply_swaps(left)
            a = a.at[j:, :j].set(left)
        if j + w < n:
            right = apply_swaps(right)
        a = a.at[j:, j:j + w].set(panel)
        ipiv = ipiv.at[j:j + w].set(piv_loc + j)
        if j + w < n:
            u12 = rtrsm_left_lower(panel[:w, :], right[:w, :], unit_diag=True)
            a = a.at[j:j + w, j + w:].set(u12)
            if j + w < m:
                l21 = panel[w:, :]
                upd = rgemm(l21, u12, right[w:, :], alpha=-1.0, beta=1.0,
                            backend=gemm_backend)
                a = a.at[j + w:, j + w:].set(upd)
    return a, ipiv


# --------------------------------------------------------------------------
# binary32 baselines
# --------------------------------------------------------------------------

def spotrf(a32: jax.Array) -> jax.Array:
    return jax.scipy.linalg.cholesky(a32.astype(jnp.float32), lower=True)


def sgetrf(a32: jax.Array):
    lu, piv = jax.scipy.linalg.lu_factor(a32.astype(jnp.float32))
    return lu, piv
