"""Rpotrs / Rgetrs — solve A x = b from the posit factorizations, plus
binary32 counterparts (the paper's §5.1 protocol uses these to measure
relative backward error).

``quire=True`` switches both substitution sweeps to the quire-exact
variants (one rounding per solved component; lapack/blas.py) — the
building block of the iterative-refinement drivers in lapack/refine.py.
``fmt`` selects the posit format of the factors/right-hand side (static,
default Posit(32,2)); the mixed-precision drivers run these in p16e1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import P32E2, PositFormat
from repro.lapack.blas import (rtrsv_lower, rtrsv_lower_quire, rtrsv_upper,
                               rtrsv_upper_quire)


def _sweeps(quire: bool):
    if quire:
        return rtrsv_lower_quire, rtrsv_upper_quire
    return rtrsv_lower, rtrsv_upper


@functools.partial(jax.jit, static_argnames=("lower", "unit_diag", "quire",
                                             "fmt"))
def rtrtrs(t_p: jax.Array, b_p: jax.Array, lower: bool = False,
           unit_diag: bool = False, quire: bool = False,
           fmt: PositFormat = P32E2) -> jax.Array:
    """Solve T x = b for triangular T (vector b) — the dtrtrs driver over
    the blas substitution sweeps.  ``quire=True`` switches to the
    quire-exact rows (one rounding per solved component) — the
    least-squares solvers' R / R^T correction sweeps (lapack/qr.py).
    The opposite triangle of ``t_p`` is never referenced (zero words and
    not-yet-solved components contribute exact zeros), so QR-factored
    matrices can be passed without masking."""
    fwd, bwd = _sweeps(quire)
    fn = fwd if lower else bwd
    return fn(t_p, b_p, unit_diag=unit_diag, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("quire", "fmt"))
def rpotrs(l_p: jax.Array, b_p: jax.Array, quire: bool = False,
           fmt: PositFormat = P32E2) -> jax.Array:
    """Solve (L L^T) x = b in posit: forward then backward substitution."""
    lower, upper = _sweeps(quire)
    y = lower(l_p, b_p, unit_diag=False, fmt=fmt)
    return upper(l_p.T, y, unit_diag=False, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("quire", "fmt"))
def rgetrs(lu_p: jax.Array, ipiv: jax.Array, b_p: jax.Array,
           quire: bool = False, fmt: PositFormat = P32E2) -> jax.Array:
    """Solve (P L U) x = b in posit."""
    def one(b, kp):
        k, p = kp
        bk, bp_ = b[k], b[p]
        return b.at[k].set(bp_).at[p].set(bk), None

    b, _ = jax.lax.scan(one, b_p, (jnp.arange(ipiv.shape[0]), ipiv))
    lower, upper = _sweeps(quire)
    y = lower(lu_p, b, unit_diag=True, fmt=fmt)
    return upper(lu_p, y, unit_diag=False, fmt=fmt)


def spotrs(l32: jax.Array, b32: jax.Array) -> jax.Array:
    return jax.scipy.linalg.cho_solve((l32, True), b32.astype(jnp.float32))


def sgetrs(lu32, piv, b32: jax.Array) -> jax.Array:
    return jax.scipy.linalg.lu_solve((lu32, piv), b32.astype(jnp.float32))
