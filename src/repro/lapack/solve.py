"""Rpotrs / Rgetrs — solve A x = b from the posit factorizations, plus
binary32 counterparts (the paper's §5.1 protocol uses these to measure
relative backward error)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.lapack.blas import rtrsv_lower, rtrsv_upper


def rpotrs(l_p: jax.Array, b_p: jax.Array) -> jax.Array:
    """Solve (L L^T) x = b in posit: forward then backward substitution."""
    y = rtrsv_lower(l_p, b_p, unit_diag=False)
    return rtrsv_upper(l_p.T, y, unit_diag=False)


def rgetrs(lu_p: jax.Array, ipiv: jax.Array, b_p: jax.Array) -> jax.Array:
    """Solve (P L U) x = b in posit."""
    def one(b, kp):
        k, p = kp
        bk, bp_ = b[k], b[p]
        return b.at[k].set(bp_).at[p].set(bk), None

    b, _ = jax.lax.scan(one, b_p, (jnp.arange(ipiv.shape[0]), ipiv))
    y = rtrsv_lower(lu_p, b, unit_diag=True)
    return rtrsv_upper(lu_p, y, unit_diag=False)


def spotrs(l32: jax.Array, b32: jax.Array) -> jax.Array:
    return jax.scipy.linalg.cho_solve((l32, True), b32.astype(jnp.float32))


def sgetrs(lu32, piv, b32: jax.Array) -> jax.Array:
    return jax.scipy.linalg.lu_solve((lu32, piv), b32.astype(jnp.float32))
