"""Rgesv_ir / Rposv_ir — quire-exact iterative refinement — and
Rgesv_mp / Rposv_mp — mixed-precision IR (factorize cheap, refine exact).

Beyond the paper's accuracy tables: the factorization runs in a working
posit format (Rgetrf/Rpotrf, any rgemm backend), and the refinement loop
recovers the digits the factorization rounds away using the quire:

    x_0 = solve(A ~= LU, b)             (quire-exact substitutions)
    repeat: r_i = b - A x_i             (EXACT fused dot per row, ONE
                                         rounding — repro.quire)
            d_i = solve(LU, r_i)
            x_{i+1} = x_i + d_i         (EXACT compensated update)

The iterate is carried as an unevaluated **posit pair** x = hi + lo (the
double-word analogue of LAPACK dsgesv's f64 carrier, in posit-native
form): a single posit32 x floors the backward error at its own storage
rounding (~2^-28 — measured, see tests/test_quire.py), while the pair
pushes the floor to ~eps^2.  Both the residual b - A*(hi+lo) and the
renormalization (hi', lo') = twosum(hi + lo + d) are EXACT in the quire
— no FastTwoSum branch games, the fixed-point accumulator just holds all
three addends.  Classic Wilkinson refinement then contracts the backward
error 4-6 decimal digits below a plain Rgetrs/Rpotrs solve on the
paper's §5.1 protocol (n=256, phi=0 ensemble; see
benchmarks/paper_tables.py::bench_refinement).

**Mixed precision** (``rgesv_mp``/``rposv_mp``, DESIGN.md §8): the
HPL-AI play on the same loop.  The O(n^3) factorization runs in a cheap
narrow format (default Posit(16,1) — ~1.2-1.3x faster end-to-end rgetrf
at n=512 in this emulation, where only the quire limb count is
format-dependent and the isolated quire update gains ~2x;
benchmarks/bench_formats.py), while the O(n^2) residual stays
quire-exact in the working format (default Posit(32,2)).  Convergence:
each sweep contracts the error by rho ~ cond(A) * eps_factor; with
eps_p16e1 ~ 2^-12 (golden zone) the contraction is ~1.7 decimal digits
per sweep for cond ~ 1e2, so the pair floor is reached in more (default
8) but cheaper iterations than ``rgesv_ir``'s 2-3 — the classic trade.
The correction solve runs entirely in the factor format; only the
residual and the compensated pair update see the working format,
bridged by one correctly-rounded narrowing each way with a power-of-two
equilibration folded in (``mp_narrow_matrix`` / ``_mp_solve_fn`` —
``posit.pconvert`` minus the scale; the narrow r -> r16 rounding is
harmless: the correction only needs the residual's leading digits).
When cond(A) * eps_factor >~ 1 the loop stalls — use ``rgesv_ir``
(full-width factorization) there; the §5.1 sigma grid in
``error_eval.mixed_precision_study`` measures exactly this envelope.

Both drivers accept b of shape (n,) or (n, nrhs); the multi-RHS form is
vmapped over columns — one factorization amortized across many scenario
solves (the serving-shaped use: one model, many right-hand sides).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P16E1, P32E2, PositFormat
from repro.lapack import decomp, solve
from repro.obs import metrics as _obs_metrics
from repro.obs import numerics as _obs_numerics
from repro.obs import trace as _obs_trace
from repro.quire import (q_to_posit, qadd_posit, quire_dot, quire_from_posit)


@functools.partial(jax.jit, static_argnames=("fmt",))
def residual_quire(a_p: jax.Array, x_p: jax.Array, b_p: jax.Array,
                   x_lo_p: jax.Array | None = None,
                   fmt: PositFormat = P32E2) -> jax.Array:
    """r = b - A (x + x_lo) with each component an exact fused dot product
    rounded once to posit (the quire residual at the heart of the
    refinement).  ``x_lo_p`` extends x to an unevaluated posit pair."""
    if x_lo_p is None:
        aa, xx = a_p, x_p
    else:
        aa = jnp.concatenate([a_p, a_p], axis=1)
        xx = jnp.concatenate([x_p, x_lo_p])
    return quire_dot(aa, xx[None, :], fmt, init_p=b_p, negate=True)


@functools.partial(jax.jit, static_argnames=("fmt",))
def pair_to_float64(x_p: jax.Array, x_lo_p: jax.Array,
                    fmt: PositFormat = P32E2) -> jax.Array:
    """Evaluate an unevaluated posit pair in binary64 (|lo| <~ ulp(hi), so
    the f64 sum is exact to f64 precision)."""
    return posit.to_float64(x_p, fmt) + posit.to_float64(x_lo_p, fmt)


def refine_pair(solve_fn, residual_fn, b_col: jax.Array, iters: int,
                fmt: PositFormat = P32E2):
    """The Wilkinson loop over an abstract solver/residual pair:

        x = solve_fn(b); repeat iters times:
            r = residual_fn(hi, lo, b)      # must be quire-exact
            d = solve_fn(r)
            (hi, lo) = exact twosum(hi + lo + d)

    ``residual_fn(x_hi, x_lo, b) -> r`` is the extension point the
    DISTRIBUTED solvers plug into (repro.dist.pdecomp wires
    ``pblas.p_residual_quire`` here — same exact fused-dot semantics,
    limb-plane psum across the grid); the single-device drivers pass a
    ``residual_quire`` closure.  ``solve_fn`` is the second extension
    point: the MIXED-PRECISION drivers wrap a narrow-format correction
    solve (factor format in, working format out) while the loop's pair
    carrier and quire updates stay in ``fmt``, and the LEAST-SQUARES
    drivers (lapack/qr.py rgels_ir/rgels_mp) plug in a rectangular
    residual b - A(hi+lo) with a semi-normal-equations correction
    solve — the loop itself never assumes the system is square.
    Returns the posit pair (x_hi, x_lo), both in ``fmt``.

    With an ``obs.scoped()`` collector open (and concrete inputs) the
    loop runs as ``_refine_pair_obs`` — the same op sequence unrolled in
    Python so each sweep can be observed: residual norm, digits gained,
    golden-zone occupancy of r, and quire limb-carry counts land in the
    ``ir.sweep`` series.
    """
    if _obs_numerics.active(b_col):
        return _refine_pair_obs(solve_fn, residual_fn, b_col, iters, fmt)
    x_hi = solve_fn(b_col)
    x_lo = jnp.zeros_like(x_hi)

    def body(carry, _):
        hi, lo = carry
        r = residual_fn(hi, lo, b_col)
        d = solve_fn(r)
        # exact compensated update: q = hi + lo + d held exactly in the
        # quire; hi' = round(q); lo' = round(q - hi') (q - hi' is exact)
        q = quire_from_posit(hi, fmt)
        q = qadd_posit(q, lo, fmt)
        q = qadd_posit(q, d, fmt)
        hi2 = q_to_posit(q, fmt)
        lo2 = q_to_posit(qadd_posit(q, hi2, fmt, negate=True), fmt)
        return (hi2, lo2), None

    (x_hi, x_lo), _ = jax.lax.scan(body, (x_hi, x_lo), None, length=iters)
    return x_hi, x_lo


def _refine_pair_obs(solve_fn, residual_fn, b_col: jax.Array, iters: int,
                     fmt: PositFormat = P32E2):
    """Observed Wilkinson loop: the SAME op sequence as ``refine_pair``'s
    scan body, unrolled in Python (scan-vs-unroll is bit-identical — the
    body is pure), with one ``ir.sweep`` series row per iteration:

        {sweep, r_norm, digits_gained, golden_frac, limb_carries}

    ``digits_gained`` is log10(||r_0|| / ||r_i||) — the per-sweep digit
    trajectory ``error_eval.golden_zone_study`` correlates with
    golden-zone occupancy.  ``limb_carries`` counts nonzero carries the
    pair-update quire releases on read-out (repro.obs.numerics).
    """
    x_hi = solve_fn(b_col)
    x_lo = jnp.zeros_like(x_hi)
    r0_norm = None
    for i in range(iters):
        with _obs_trace.span("ir.sweep", sweep=i):
            r = residual_fn(x_hi, x_lo, b_col)
            d = solve_fn(r)
            q = quire_from_posit(x_hi, fmt)
            q = qadd_posit(q, x_lo, fmt)
            q = qadd_posit(q, d, fmt)
            hi2 = q_to_posit(q, fmt)
            lo2 = q_to_posit(qadd_posit(q, hi2, fmt, negate=True), fmt)

            r_norm = float(jnp.max(jnp.abs(posit.to_float64(r, fmt))))
            if r0_norm is None:
                r0_norm = r_norm if r_norm > 0 else 1.0
            digits = float(jnp.log10(r0_norm / max(r_norm, 1e-300)))
            st = _obs_numerics.step_stats(r, fmt)
            carries = _obs_numerics.quire_carry_stats(q.limbs)
            _obs_metrics.record("ir.sweep", sweep=i, r_norm=r_norm,
                                digits_gained=digits,
                                golden_frac=float(st["golden_frac"]),
                                limb_carries=int(carries["total"]))
        x_hi, x_lo = hi2, lo2
    _obs_metrics.inc("ir.sweeps", iters)
    return x_hi, x_lo


def _driver(a_p, b_p, solve_fn, iters, fmt: PositFormat = P32E2):
    b_p = jnp.asarray(b_p, jnp.int32)
    residual_fn = lambda hi, lo, b: residual_quire(a_p, hi, b, lo, fmt=fmt)
    one = functools.partial(refine_pair, solve_fn, residual_fn, iters=iters,
                            fmt=fmt)
    if b_p.ndim == 1:
        return one(b_p)
    if _obs_numerics.active(a_p, b_p):
        # Observed path: loop the columns (vmap-vs-loop bit-identity is
        # pinned by the repo's refinement tests) so each column's sweeps
        # land in the ir.sweep series.
        cols = [one(b_p[:, j]) for j in range(b_p.shape[1])]
        return (jnp.stack([hi for hi, _ in cols], axis=1),
                jnp.stack([lo for _, lo in cols], axis=1))
    return jax.vmap(one, in_axes=1, out_axes=1)(b_p)


def rgesv_ir(a_p: jax.Array, b_p: jax.Array, iters: int = 3, nb: int = 32,
             gemm_backend: str = "xla_quire", fmt: PositFormat = P32E2):
    """LU-based solve of A x = b with quire-exact iterative refinement.

    Returns ((x_hi, x_lo), (lu, ipiv)): the solution is the unevaluated
    posit pair x_hi + x_lo (use x_hi alone for a plain posit32 result, or
    ``pair_to_float64`` for the full refined value).  b may be (n,) or
    (n, nrhs) (vmapped over columns).  A batched a_p of shape
    (batch, n, n) (with matching leading axis on b) vmaps the whole
    driver — factorizations and refinement sweeps run as one batched
    program on top of the single-dispatch ``rgetrf``.
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rgesv_ir(a, b, iters, nb, gemm_backend,
                                              fmt)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    lu, ipiv = decomp.rgetrf(a_p, nb=nb, gemm_backend=gemm_backend, fmt=fmt)
    solve_fn = lambda r: solve.rgetrs(lu, ipiv, r, quire=True, fmt=fmt)
    return _driver(a_p, b_p, solve_fn, iters, fmt), (lu, ipiv)


def rposv_ir(a_p: jax.Array, b_p: jax.Array, iters: int = 3, nb: int = 32,
             gemm_backend: str = "xla_quire", fmt: PositFormat = P32E2):
    """Cholesky-based SPD solve with quire-exact iterative refinement.

    Returns ((x_hi, x_lo), l); same conventions (including batched a_p)
    as ``rgesv_ir``.
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rposv_ir(a, b, iters, nb, gemm_backend,
                                              fmt)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    l_p = decomp.rpotrf(a_p, nb=nb, gemm_backend=gemm_backend, fmt=fmt)
    solve_fn = lambda r: solve.rpotrs(l_p, r, quire=True, fmt=fmt)
    return _driver(a_p, b_p, solve_fn, iters, fmt), l_p


# --------------------------------------------------------------------------
# mixed-precision IR: narrow-format factorization, working-format residual
# --------------------------------------------------------------------------

def pow2_scale(x64):
    """2^floor(log2(max|x|)) — the exact-in-f64 equilibration scale
    bringing max|x| into [1, 2) (NaN lanes ignored; 1.0 for all-zero)."""
    mx = jnp.max(jnp.abs(jnp.where(jnp.isnan(x64), 0.0, x64)))
    safe = jnp.where(mx > 0, mx, 1.0)
    return jnp.exp2(jnp.floor(jnp.log2(safe)))


def mp_narrow_matrix(a_p, factor_fmt: PositFormat, fmt: PositFormat):
    """A -> (A/s rounded to factor_fmt, s) with s a power of two placing
    max|A| in [1, 2) — posit-aware matrix equilibration.  The narrow
    format's fraction bits peak in the golden zone around 1, so scaling A
    there makes the factorization's relative error (and hence the IR
    contraction rate) independent of the problem's sigma/phi scale; the
    paper's "accuracy depends on operand scale" effect, turned around
    and used.  s is folded back in the correction solve: A = s * A'
    => A^{-1} r = (1/s) * A'^{-1} r.  Exact: s is a power of two applied
    in the f64 carrier."""
    av = posit.to_float64(a_p, fmt)
    s = pow2_scale(av)
    return posit.from_float64(av / s, factor_fmt), s


def _mp_solve_fn(base_solve, a_scale, factor_fmt: PositFormat,
                 fmt: PositFormat):
    """Wrap a factor-format solve as a working-format correction solve:
    round r down (the correction only needs r's leading digits), solve in
    the cheap format, lift d back up.

    The residual is **equilibrated** too (the HPL-AI/dsgesv trick, in
    posit terms): as refinement converges, ||r|| shrinks toward — and
    past — the narrow format's golden zone, where p16e1 keeps almost no
    fraction bits (and underflows entirely at minpos = 2^-28), stalling
    the contraction at ~1e-8 backward error.  Scaling by the power of two
    that brings max|r| to [1, 2) puts every component at the format's
    maximum-precision regime; the solve is scale-invariant, and the
    power-of-two scale/unscale is exact in the f64 carrier (posit values
    are exactly f64-representable), so the only roundings are the r -> r16
    narrowing and the final d encode — the same two any narrow solve has.
    ``a_scale`` is the matrix equilibration scale from
    ``mp_narrow_matrix`` (the factors are of A/a_scale, so the
    correction gains a 1/a_scale).
    """
    def solve_fn(r):
        rv = posit.to_float64(r, fmt)
        s = pow2_scale(rv)
        r_lo = posit.from_float64(rv / s, factor_fmt)
        d_lo = posit.to_float64(base_solve(r_lo), factor_fmt)
        return posit.from_float64(d_lo * (s / a_scale), fmt)
    return solve_fn


def rgesv_mp(a_p: jax.Array, b_p: jax.Array, iters: int = 8, nb: int = 32,
             gemm_backend: str = "xla_quire",
             factor_fmt: PositFormat = P16E1, fmt: PositFormat = P32E2):
    """Mixed-precision LU solve: factorize A in ``factor_fmt`` (default
    Posit(16,1) — the cheap O(n^3) step), refine with ``fmt`` (default
    Posit(32,2)) quire-exact residuals until the pair floor.

    A, b, and the returned pair (x_hi, x_lo) are ``fmt`` words; the
    returned factors (lu, ipiv) are ``factor_fmt`` words.  Same (n,) /
    (n, nrhs) / batched-A conventions as ``rgesv_ir``.  Reaches the same
    backward-error digits as ``rgesv_ir`` wherever
    cond(A) * eps_factor < 1 (the §5.1 sigma grid in
    ``error_eval.mixed_precision_study``), in more but much cheaper
    iterations — see the module docstring for the convergence argument.
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rgesv_mp(a, b, iters, nb, gemm_backend,
                                              factor_fmt, fmt)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    a_lo, a_scale = mp_narrow_matrix(a_p, factor_fmt, fmt)
    lu, ipiv = decomp.rgetrf(a_lo, nb=nb, gemm_backend=gemm_backend,
                             fmt=factor_fmt)
    base = lambda r16: solve.rgetrs(lu, ipiv, r16, quire=True,
                                    fmt=factor_fmt)
    solve_fn = _mp_solve_fn(base, a_scale, factor_fmt, fmt)
    return _driver(a_p, b_p, solve_fn, iters, fmt), (lu, ipiv)


def rposv_mp(a_p: jax.Array, b_p: jax.Array, iters: int = 16, nb: int = 32,
             gemm_backend: str = "xla_quire",
             factor_fmt: PositFormat = P16E1, fmt: PositFormat = P32E2):
    """Mixed-precision SPD solve: Cholesky in ``factor_fmt``, quire-exact
    ``fmt`` refinement.  Returns ((x_hi, x_lo), l) with l in
    ``factor_fmt``; same conventions as ``rgesv_mp``.  The default sweep
    count is higher than ``rgesv_mp``'s: the §5.1 SPD ensemble is
    A = X^T X, whose condition number is cond(X)^2, and the contraction
    rho ~ cond(A) * eps_p16e1 is correspondingly slower.  The narrow
    rounding of A must preserve positive-definiteness (a diagonally
    dominant or well-conditioned SPD A survives p16e1's ~2^-12 relative
    perturbation; a barely-SPD A may not — NaR from sqrt poisons the
    factor, and the returned pair will be NaR too, which is the correct
    failure signal).
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rposv_mp(a, b, iters, nb, gemm_backend,
                                              factor_fmt, fmt)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    a_lo, a_scale = mp_narrow_matrix(a_p, factor_fmt, fmt)
    l_p = decomp.rpotrf(a_lo, nb=nb, gemm_backend=gemm_backend,
                        fmt=factor_fmt)
    base = lambda r16: solve.rpotrs(l_p, r16, quire=True, fmt=factor_fmt)
    solve_fn = _mp_solve_fn(base, a_scale, factor_fmt, fmt)
    return _driver(a_p, b_p, solve_fn, iters, fmt), l_p


# --------------------------------------------------------------------------
# graceful degradation: convergence monitor + escalation ladder (repro.ft,
# DESIGN.md §11)
# --------------------------------------------------------------------------

def refine_pair_monitored(solve_fn, residual_fn, b_col: jax.Array,
                          max_sweeps: int, fmt: PositFormat = P32E2,
                          target: float = 1e-10, patience: int = 2,
                          growth: float = 4.0):
    """``refine_pair`` with a host-level convergence monitor.

    The SAME per-sweep op sequence as ``refine_pair``'s scan body (so a
    run that converges in k sweeps yields the pair bit-identical to
    ``refine_pair(..., iters=k)``), unrolled in Python like
    ``_refine_pair_obs`` so each sweep's residual norm is a concrete
    host value the monitor can act on:

    * ``nar``       — NaR appeared in the residual or the iterate (a
      poisoned narrow factorization, an injected NaR, a singular
      correction solve): stop immediately, the pair cannot recover.
    * ``diverged``  — ||r|| grew by more than ``growth`` over a sweep
      and exceeds ||r_0||: the correction solve is amplifying, not
      contracting (cond * eps_factor >> 1).
    * ``stalled``   — ``patience`` consecutive sweeps without halving
      the best ||r|| seen, while still above target: contraction has
      flattened out (the classic mixed-precision stall,
      cond * eps_factor >~ 1).
    * ``converged`` — ||r||_inf <= ``target`` * ||b||_inf (backward-
      error-style test; exact zero converges trivially).

    Returns ((x_hi, x_lo), info dict) with info carrying outcome, the
    number of correction updates applied (``sweeps`` — so
    ``refine_pair(..., iters=sweeps)`` reproduces the pair exactly), and
    the first/last residual norms — ``rgesv_guarded`` folds these into
    its ``SolveReport``.
    """
    b_norm = float(jnp.max(jnp.abs(posit.to_float64(b_col, fmt))))
    tol = target * (b_norm if b_norm > 0 else 1.0)
    x_hi = solve_fn(b_col)
    x_lo = jnp.zeros_like(x_hi)
    outcome = "stalled"                    # if the sweep budget runs out
    r0_norm = r_norm = float("inf")
    best = float("inf")
    flat = 0
    sweeps = 0
    for i in range(max_sweeps):
        r = residual_fn(x_hi, x_lo, b_col)
        if bool(jnp.any(posit.is_nar(r, fmt))
                | jnp.any(posit.is_nar(x_hi, fmt))):
            outcome = "nar"
            break
        prev = r_norm
        r_norm = float(jnp.max(jnp.abs(posit.to_float64(r, fmt))))
        if i == 0:
            r0_norm = r_norm
        if r_norm <= tol:
            outcome = "converged"
            break
        if r_norm > growth * prev and r_norm > r0_norm:
            outcome = "diverged"
            break
        if r_norm > 0.5 * best:
            flat += 1
            if flat >= patience:
                outcome = "stalled"
                break
        else:
            flat = 0
        best = min(best, r_norm)
        d = solve_fn(r)
        q = quire_from_posit(x_hi, fmt)
        q = qadd_posit(q, x_lo, fmt)
        q = qadd_posit(q, d, fmt)
        hi2 = q_to_posit(q, fmt)
        lo2 = q_to_posit(qadd_posit(q, hi2, fmt, negate=True), fmt)
        x_hi, x_lo = hi2, lo2
        sweeps = i + 1
    info = {"outcome": outcome, "sweeps": sweeps, "r_norm": r_norm,
            "r_norm0": r0_norm}
    _obs_metrics.inc(f"ir.monitor.{outcome}")
    return (x_hi, x_lo), info


def _guarded_cols(a_p, b_p, solve_fn, max_sweeps, fmt, target):
    """Run the monitored loop per RHS column; merge to the WORST info
    (a ladder rung only counts as converged if every column converged)."""
    b_p = jnp.asarray(b_p, jnp.int32)
    residual_fn = lambda hi, lo, b: residual_quire(a_p, hi, b, lo, fmt=fmt)
    if b_p.ndim == 1:
        return refine_pair_monitored(solve_fn, residual_fn, b_p, max_sweeps,
                                     fmt, target=target)
    rank = {"converged": 0, "stalled": 1, "diverged": 2, "nar": 3}
    cols, worst = [], None
    for j in range(b_p.shape[1]):
        pair, info = refine_pair_monitored(solve_fn, residual_fn, b_p[:, j],
                                           max_sweeps, fmt, target=target)
        cols.append(pair)
        if worst is None or rank[info["outcome"]] > rank[worst["outcome"]]:
            worst = info
    return (jnp.stack([hi for hi, _ in cols], axis=1),
            jnp.stack([lo for _, lo in cols], axis=1)), worst


def rgesv_guarded(a_p: jax.Array, b_p: jax.Array, iters: int = 8,
                  nb: int = 32, gemm_backend: str = "xla_quire",
                  factor_fmt: PositFormat = P16E1,
                  fmt: PositFormat = P32E2, target: float = 1e-10,
                  plan=None, max_retries: int = 2):
    """Gracefully-degrading LU solve: the full robustness ladder.

        rgesv_mp (cheap narrow factorization, monitored refinement)
          -> stalls / diverges / NaRs ->
        rgesv_ir (full-width factorization, monitored refinement)
          -> still won't meet target ->
        plain rgetrs backsolve on the protected full-width factors
        (best-effort answer, reported as outcome="plain")

    Every factorization in the ladder is the checksum-PROTECTED
    ``rgetrf_ft`` (repro.ft exact ABFT): storage faults injected via
    ``plan`` are detected and repaired before the refinement loop ever
    sees them, and the detection/retry counts land in the returned
    ``SolveReport`` alongside the monitor outcome.  Returns
    ((x_hi, x_lo), SolveReport).  b may be (n,) or (n, nrhs); with
    multiple RHS the report reflects the worst column.
    """
    from repro.ft.report import SolveReport
    a_p = jnp.asarray(a_p, jnp.int32)
    detections = retries = 0
    fallbacks = []

    # rung 1: mixed precision
    a_lo, a_scale = mp_narrow_matrix(a_p, factor_fmt, fmt)
    lu16, piv16, ft_rep = decomp.rgetrf_ft(a_lo, nb=nb,
                                           gemm_backend=gemm_backend,
                                           fmt=factor_fmt, plan=plan,
                                           max_retries=max_retries)
    detections += ft_rep.detections
    retries += ft_rep.retries
    base = lambda r16: solve.rgetrs(lu16, piv16, r16, quire=True,
                                    fmt=factor_fmt)
    pair, info = _guarded_cols(a_p, b_p,
                               _mp_solve_fn(base, a_scale, factor_fmt, fmt),
                               iters, fmt, target)
    if info["outcome"] == "converged":
        return pair, SolveReport(outcome="converged", solver="rgesv_mp",
                                 sweeps=info["sweeps"],
                                 r_norm=info["r_norm"],
                                 r_norm0=info["r_norm0"],
                                 detections=detections, retries=retries)
    fallbacks.append(("rgesv_mp", info["outcome"]))
    _obs_metrics.inc("ft.fallbacks")

    # rung 2: full-width iterative refinement
    lu, ipiv, ft_rep = decomp.rgetrf_ft(a_p, nb=nb,
                                        gemm_backend=gemm_backend, fmt=fmt,
                                        plan=plan, max_retries=max_retries)
    detections += ft_rep.detections
    retries += ft_rep.retries
    solve_fn = lambda r: solve.rgetrs(lu, ipiv, r, quire=True, fmt=fmt)
    pair, info = _guarded_cols(a_p, b_p, solve_fn, iters, fmt, target)
    if info["outcome"] == "converged":
        return pair, SolveReport(outcome="converged", solver="rgesv_ir",
                                 sweeps=info["sweeps"],
                                 r_norm=info["r_norm"],
                                 r_norm0=info["r_norm0"],
                                 detections=detections, retries=retries,
                                 fallbacks=tuple(fallbacks))
    fallbacks.append(("rgesv_ir", info["outcome"]))
    _obs_metrics.inc("ft.fallbacks")

    # rung 3: plain backsolve on the (already protected) full factors —
    # best effort, no refinement claims
    b_w = jnp.asarray(b_p, jnp.int32)
    x = solve.rgetrs(lu, ipiv, b_w, quire=True, fmt=fmt)
    return (x, jnp.zeros_like(x)), SolveReport(
        outcome="plain", solver="rgetrs", sweeps=info["sweeps"],
        r_norm=info["r_norm"], r_norm0=info["r_norm0"],
        detections=detections, retries=retries, fallbacks=tuple(fallbacks))
