"""Rgesv_ir / Rposv_ir — mixed-precision iterative-refinement solvers.

Beyond the paper's accuracy tables: the factorization runs in working
Posit(32,2) (Rgetrf/Rpotrf, any rgemm backend), and the refinement loop
recovers the digits the factorization rounds away using the quire:

    x_0 = solve(A ~= LU, b)             (quire-exact substitutions)
    repeat: r_i = b - A x_i             (EXACT fused dot per row, ONE
                                         rounding — repro.quire)
            d_i = solve(LU, r_i)
            x_{i+1} = x_i + d_i         (EXACT compensated update)

The iterate is carried as an unevaluated **posit pair** x = hi + lo (the
double-word analogue of LAPACK dsgesv's f64 carrier, in posit-native
form): a single posit32 x floors the backward error at its own storage
rounding (~2^-28 — measured, see tests/test_quire.py), while the pair
pushes the floor to ~eps^2.  Both the residual b - A*(hi+lo) and the
renormalization (hi', lo') = twosum(hi + lo + d) are EXACT in the quire
— no FastTwoSum branch games, the fixed-point accumulator just holds all
three addends.  Classic Wilkinson refinement then contracts the backward
error 4-6 decimal digits below a plain Rgetrs/Rpotrs solve on the
paper's §5.1 protocol (n=256, phi=0 ensemble; see
benchmarks/paper_tables.py::bench_refinement).

Both drivers accept b of shape (n,) or (n, nrhs); the multi-RHS form is
vmapped over columns — one factorization amortized across many scenario
solves (the serving-shaped use: one model, many right-hand sides).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P32E2
from repro.lapack import decomp, solve
from repro.quire import (q_to_posit, qadd_posit, quire_dot, quire_from_posit)

_FMT = P32E2


@jax.jit
def residual_quire(a_p: jax.Array, x_p: jax.Array, b_p: jax.Array,
                   x_lo_p: jax.Array | None = None) -> jax.Array:
    """r = b - A (x + x_lo) with each component an exact fused dot product
    rounded once to posit (the quire residual at the heart of the
    refinement).  ``x_lo_p`` extends x to an unevaluated posit pair."""
    if x_lo_p is None:
        aa, xx = a_p, x_p
    else:
        aa = jnp.concatenate([a_p, a_p], axis=1)
        xx = jnp.concatenate([x_p, x_lo_p])
    return quire_dot(aa, xx[None, :], _FMT, init_p=b_p, negate=True)


@jax.jit
def pair_to_float64(x_p: jax.Array, x_lo_p: jax.Array) -> jax.Array:
    """Evaluate an unevaluated posit pair in binary64 (|lo| <~ ulp(hi), so
    the f64 sum is exact to f64 precision)."""
    return posit.to_float64(x_p, _FMT) + posit.to_float64(x_lo_p, _FMT)


def refine_pair(solve_fn, residual_fn, b_col: jax.Array, iters: int):
    """The Wilkinson loop over an abstract solver/residual pair:

        x = solve_fn(b); repeat iters times:
            r = residual_fn(hi, lo, b)      # must be quire-exact
            d = solve_fn(r)
            (hi, lo) = exact twosum(hi + lo + d)

    ``residual_fn(x_hi, x_lo, b) -> r`` is the extension point the
    DISTRIBUTED solvers plug into (repro.dist.pdecomp wires
    ``pblas.p_residual_quire`` here — same exact fused-dot semantics,
    limb-plane psum across the grid); the single-device drivers pass a
    ``residual_quire`` closure.  Returns the posit pair (x_hi, x_lo).
    """
    x_hi = solve_fn(b_col)
    x_lo = jnp.zeros_like(x_hi)

    def body(carry, _):
        hi, lo = carry
        r = residual_fn(hi, lo, b_col)
        d = solve_fn(r)
        # exact compensated update: q = hi + lo + d held exactly in the
        # quire; hi' = round(q); lo' = round(q - hi') (q - hi' is exact)
        q = quire_from_posit(hi, _FMT)
        q = qadd_posit(q, lo, _FMT)
        q = qadd_posit(q, d, _FMT)
        hi2 = q_to_posit(q, _FMT)
        lo2 = q_to_posit(qadd_posit(q, hi2, _FMT, negate=True), _FMT)
        return (hi2, lo2), None

    (x_hi, x_lo), _ = jax.lax.scan(body, (x_hi, x_lo), None, length=iters)
    return x_hi, x_lo


def _driver(a_p, b_p, solve_fn, iters):
    b_p = jnp.asarray(b_p, jnp.int32)
    residual_fn = lambda hi, lo, b: residual_quire(a_p, hi, b, lo)
    one = functools.partial(refine_pair, solve_fn, residual_fn, iters=iters)
    if b_p.ndim == 1:
        return one(b_p)
    return jax.vmap(one, in_axes=1, out_axes=1)(b_p)


def rgesv_ir(a_p: jax.Array, b_p: jax.Array, iters: int = 3, nb: int = 32,
             gemm_backend: str = "xla_quire"):
    """LU-based solve of A x = b with quire-exact iterative refinement.

    Returns ((x_hi, x_lo), (lu, ipiv)): the solution is the unevaluated
    posit pair x_hi + x_lo (use x_hi alone for a plain posit32 result, or
    ``pair_to_float64`` for the full refined value).  b may be (n,) or
    (n, nrhs) (vmapped over columns).  A batched a_p of shape
    (batch, n, n) (with matching leading axis on b) vmaps the whole
    driver — factorizations and refinement sweeps run as one batched
    program on top of the single-dispatch ``rgetrf``.
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rgesv_ir(a, b, iters, nb, gemm_backend)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    lu, ipiv = decomp.rgetrf(a_p, nb=nb, gemm_backend=gemm_backend)
    solve_fn = lambda r: solve.rgetrs(lu, ipiv, r, quire=True)
    return _driver(a_p, b_p, solve_fn, iters), (lu, ipiv)


def rposv_ir(a_p: jax.Array, b_p: jax.Array, iters: int = 3, nb: int = 32,
             gemm_backend: str = "xla_quire"):
    """Cholesky-based SPD solve with quire-exact iterative refinement.

    Returns ((x_hi, x_lo), l); same conventions (including batched a_p)
    as ``rgesv_ir``.
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    if a_p.ndim == 3:
        return jax.vmap(lambda a, b: rposv_ir(a, b, iters, nb, gemm_backend)
                        )(a_p, jnp.asarray(b_p, jnp.int32))
    l_p = decomp.rpotrf(a_p, nb=nb, gemm_backend=gemm_backend)
    solve_fn = lambda r: solve.rpotrs(l_p, r, quire=True)
    return _driver(a_p, b_p, solve_fn, iters), l_p
