"""Posit BLAS-2/3 building blocks (triangular solves, rank-1 updates).

Every scalar operation is a rounded posit op (fast backend) in the
working format ``fmt`` (static, default Posit(32,2)), in the same
operation order as reference-BLAS dtrsm/dtrsv (rank-1 / axpy form) —
this is what "running LAPACK in posit" via MPLAPACK does on the host in
the paper, with only Rgemm offloaded to the accelerator.  One traced
program serves every registered format; the format's field constants
fold at trace time (DESIGN.md §8).

All matrices are int32 posit-word arrays of the ONE format ``fmt``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P32E2, PositFormat
from repro.quire import quire_dot


def _div(a, b, fmt: PositFormat = P32E2):
    """Word-domain rounded divide — used where the operand is already a
    posit word (the quire substitutions' fused-dot results)."""
    return posit.div(a, b, fmt, backend="fast")


@functools.partial(jax.jit, static_argnames=("unit_diag", "fmt"))
def rtrsm_left_lower(l_p: jax.Array, b_p: jax.Array, unit_diag: bool = True,
                     fmt: PositFormat = P32E2) -> jax.Array:
    """Solve L X = B, L (n,n) lower-triangular posit, B (n, m) posit.

    Forward substitution in rank-1-update order: n steps, each a
    vectorized posit mul+sub over the remaining rows.  Fused-chain
    execution (core/posit.py): L and B decode to f64 once, each scalar op
    is still individually posit-rounded, words are packed once at exit —
    bit-identical to per-op fast-backend words.
    """
    n = l_p.shape[0]
    rows = jnp.arange(n)
    lv = posit.chain_decode(l_p, fmt)

    def step(b, k):
        xk = b[k, :] if unit_diag else posit.chain_div(b[k, :], lv[k, k],
                                                       fmt)
        upd = posit.chain_sub(b, posit.chain_mul(lv[:, k][:, None],
                                                 xk[None, :], fmt), fmt)
        mask = (rows > k)[:, None]
        b = jnp.where(mask, upd, b)
        b = b.at[k, :].set(xk)
        return b, None

    x, _ = jax.lax.scan(step, posit.chain_decode(b_p, fmt), jnp.arange(n))
    return posit.chain_encode(x, fmt)


@functools.partial(jax.jit, static_argnames=("unit_diag", "fmt"))
def rtrsm_left_upper(u_p: jax.Array, b_p: jax.Array, unit_diag: bool = False,
                     fmt: PositFormat = P32E2) -> jax.Array:
    """Solve U X = B, U (n,n) upper-triangular posit, B (n, m) posit.

    Backward substitution in rank-1-update order (the dtrsm mirror of
    ``rtrsm_left_lower``) — Rgels' final R x = Q^T b solve.  Fused-chain
    execution; the strict lower triangle of U is never referenced, so a
    QR-factored matrix (reflector tails below the diagonal) can be
    passed as-is.
    """
    n = u_p.shape[0]
    rows = jnp.arange(n)
    uv = posit.chain_decode(u_p, fmt)

    def step(b, k):
        xk = b[k, :] if unit_diag else posit.chain_div(b[k, :], uv[k, k],
                                                       fmt)
        upd = posit.chain_sub(b, posit.chain_mul(uv[:, k][:, None],
                                                 xk[None, :], fmt), fmt)
        mask = (rows < k)[:, None]
        b = jnp.where(mask, upd, b)
        b = b.at[k, :].set(xk)
        return b, None

    x, _ = jax.lax.scan(step, posit.chain_decode(b_p, fmt),
                        jnp.arange(n - 1, -1, -1))
    return posit.chain_encode(x, fmt)


@functools.partial(jax.jit, static_argnames=("fmt",))
def rtrsm_right_lowerT(b_p: jax.Array, l_p: jax.Array,
                       fmt: PositFormat = P32E2) -> jax.Array:
    """Solve X L^T = B  (right, lower-transpose, non-unit diag).

    Used by Cholesky's panel update A21 <- A21 * L11^{-T}.  Right-looking
    column order: X[:,k] = B[:,k] / L[k,k]; B[:,j>k] -= X[:,k] L[j,k].
    Fused-chain execution; bit-identical to the word-domain form.
    """
    n = l_p.shape[0]
    cols = jnp.arange(n)
    lv = posit.chain_decode(l_p, fmt)

    def step(b, k):
        xk = posit.chain_div(b[:, k], lv[k, k], fmt)
        upd = posit.chain_sub(b, posit.chain_mul(xk[:, None],
                                                 lv[:, k][None, :], fmt),
                              fmt)
        mask = (cols > k)[None, :]
        b = jnp.where(mask, upd, b)
        b = b.at[:, k].set(xk)
        return b, None

    x, _ = jax.lax.scan(step, posit.chain_decode(b_p, fmt), jnp.arange(n))
    return posit.chain_encode(x, fmt)


@functools.partial(jax.jit, static_argnames=("unit_diag", "fmt"))
def rtrsv_lower(l_p: jax.Array, b_p: jax.Array, unit_diag: bool = False,
                fmt: PositFormat = P32E2) -> jax.Array:
    """Solve L x = b (vector), forward substitution with posit axpy steps
    (fused-chain form, bit-identical to per-op words)."""
    n = l_p.shape[0]
    idx = jnp.arange(n)
    lv = posit.chain_decode(l_p, fmt)

    def step(b, k):
        xk = b[k] if unit_diag else posit.chain_div(b[k], lv[k, k], fmt)
        upd = posit.chain_sub(b, posit.chain_mul(lv[:, k], xk, fmt), fmt)
        b = jnp.where(idx > k, upd, b)
        b = b.at[k].set(xk)
        return b, None

    x, _ = jax.lax.scan(step, posit.chain_decode(b_p, fmt), jnp.arange(n))
    return posit.chain_encode(x, fmt)


@functools.partial(jax.jit, static_argnames=("unit_diag", "fmt"))
def rtrsv_upper(u_p: jax.Array, b_p: jax.Array, unit_diag: bool = False,
                fmt: PositFormat = P32E2) -> jax.Array:
    """Solve U x = b (vector), backward substitution with posit axpy steps
    (fused-chain form, bit-identical to per-op words)."""
    n = u_p.shape[0]
    idx = jnp.arange(n)
    uv = posit.chain_decode(u_p, fmt)

    def step(b, k):
        xk = b[k] if unit_diag else posit.chain_div(b[k], uv[k, k], fmt)
        upd = posit.chain_sub(b, posit.chain_mul(uv[:, k], xk, fmt), fmt)
        b = jnp.where(idx < k, upd, b)
        b = b.at[k].set(xk)
        return b, None

    x, _ = jax.lax.scan(step, posit.chain_decode(b_p, fmt),
                        jnp.arange(n - 1, -1, -1))
    return posit.chain_encode(x, fmt)


# --------------------------------------------------------------------------
# Householder reflector helper (the dlarfg kernel, fused-chain form) —
# the scalar engine of lapack/qr.py's panel factorization
# --------------------------------------------------------------------------

def rlarfg_chain(col: jax.Array, k, fmt: PositFormat = P32E2):
    """Generate the Householder reflector H = I - tau v v^T annihilating
    ``col`` below index ``k`` (dlarfg, every scalar op posit-rounded).

    ``col`` is a fused-chain (decoded f64) column; ``k`` the pivot index
    (traced).  Returns chain-domain ``(newcol, v, tau)``:

    * ``newcol`` — beta = -sign(alpha) * ||col[k:]|| at index k (no
      cancellation), the reflector tail v[k+1:] below it, rows < k
      untouched;
    * ``v``      — the full reflector: 0 above k, exactly 1 at k;
    * ``tau``    — (beta - alpha) / beta, or 0 for an already-zero tail
      (H = I, the dlarfg trivial case — also what a zero-height tail in
      the last panel column produces).
    """
    m = col.shape[0]
    rows = jnp.arange(m)

    def acc(s, i):
        upd = posit.chain_add(s, posit.chain_mul(col[i], col[i], fmt), fmt)
        return jnp.where(i > k, upd, s), None

    s2, _ = jax.lax.scan(acc, jnp.float64(0.0), rows)
    alpha = col[k]
    norm = posit.chain_sqrt(
        posit.chain_add(posit.chain_mul(alpha, alpha, fmt), s2, fmt), fmt)
    # posit rounding saturates at minpos (never flushes to zero), so
    # s2 == 0 iff every tail element is exactly zero
    trivial = s2 == 0.0
    beta = jnp.where(alpha > 0, -norm, norm)
    tau = jnp.where(trivial, 0.0,
                    posit.chain_div(posit.chain_sub(beta, alpha, fmt), beta,
                                    fmt))
    denom = posit.chain_sub(alpha, beta, fmt)
    tail = posit.chain_div(col, denom, fmt)
    v = jnp.where(rows == k, 1.0,
                  jnp.where((rows > k) & ~trivial, tail, 0.0))
    newcol = jnp.where(
        rows == k, jnp.where(trivial, alpha, beta),
        jnp.where(rows > k, jnp.where(trivial, col, tail), col))
    return newcol, v, tau


# --------------------------------------------------------------------------
# quire-backed substitutions: the per-row inner product is an exact fused
# dot (repro.quire), so each solved component suffers exactly ONE rounding
# before the divide instead of n rounded axpy steps — the accuracy lever
# the iterative-refinement drivers (lapack/refine.py) are built on.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("unit_diag", "fmt"))
def rtrsv_lower_quire(l_p: jax.Array, b_p: jax.Array, unit_diag: bool = False,
                      fmt: PositFormat = P32E2) -> jax.Array:
    """Solve L x = b with quire-exact rows:
    x_k = round(b_k - fdp(L[k, :k], x[:k])) / L_kk."""
    n = l_p.shape[0]
    x0 = jnp.zeros_like(jnp.asarray(b_p, jnp.int32))

    def step(x, k):
        # x[j] == 0 (posit zero word) for j >= k, so the full-row fused
        # dot only picks up the already-solved prefix — no masking needed.
        rk = quire_dot(l_p[k, :], x, fmt, init_p=b_p[k], negate=True)
        xk = rk if unit_diag else _div(rk, l_p[k, k], fmt)
        return x.at[k].set(xk), None

    x, _ = jax.lax.scan(step, x0, jnp.arange(n))
    return x


@functools.partial(jax.jit, static_argnames=("unit_diag", "fmt"))
def rtrsv_upper_quire(u_p: jax.Array, b_p: jax.Array, unit_diag: bool = False,
                      fmt: PositFormat = P32E2) -> jax.Array:
    """Solve U x = b, backward substitution with quire-exact rows."""
    n = u_p.shape[0]
    x0 = jnp.zeros_like(jnp.asarray(b_p, jnp.int32))

    def step(x, k):
        rk = quire_dot(u_p[k, :], x, fmt, init_p=b_p[k], negate=True)
        xk = rk if unit_diag else _div(rk, u_p[k, k], fmt)
        return x.at[k].set(xk), None

    x, _ = jax.lax.scan(step, x0, jnp.arange(n - 1, -1, -1))
    return x
