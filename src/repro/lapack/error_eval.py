"""The paper's §5.1 numerical-error protocol (Eqs. 4-5), format-parametric.

x_sol = (1/sqrt(N)) * ones; b = A @ x_sol in binary64; solve in posit
format ``fmt`` (Rpotrf+Rpotrs or Rgetrf+Rgetrs) and in binary32
(Spotrf+Spotrs / Sgetrf+Sgetrs); report

    e = |b - A x_hat| / |b|           (relative backward error, 2-norm)
    digits = log10(e_binary32 / e_posit)   (paper Fig. 7; > 0 => posit wins)

The paper runs this for Posit(32,2) only; with the format-parametric
stack the same protocol sweeps p16e1/p8e2 (Ciocirlan et al.'s width
sweep), and ``mixed_precision_study`` runs it for the HPL-AI-style
rgesv_mp/rposv_mp drivers (p16e1 factorization + p32e2 quire refinement)
against full-width rgesv_ir/rposv_ir — the accuracy half of the
speed-vs-accuracy trade benchmarks/bench_formats.py times.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posit
from repro.core.formats import P32E2, PositFormat
from repro.lapack import decomp, qr, refine, solve
from repro import obs


def make_spd(n: int, sigma: float, seed: int = 0) -> np.ndarray:
    """A = X^T X with X ~ N(0, sigma) — the paper's Rpotrf input."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, n)) * sigma
    return x.T @ x


def make_general(n: int, sigma: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, n)) * sigma


@dataclasses.dataclass
class ErrorResult:
    n: int
    sigma: float
    algo: str
    e_posit: float
    e_binary32: float
    fmt: str = "p32e2"

    @property
    def digits(self) -> float:
        return float(np.log10(self.e_binary32 / self.e_posit))


def _backward_error(a64: np.ndarray, xhat64: np.ndarray, b64: np.ndarray
                    ) -> float:
    r = b64 - a64 @ xhat64
    return float(np.linalg.norm(r) / np.linalg.norm(b64))


def backward_error_study(n: int, sigma: float, algo: str = "lu",
                         seed: int = 0, nb: int = 32,
                         gemm_backend: str = "faithful",
                         fmt: PositFormat = P32E2) -> ErrorResult:
    """Run the full §5.1 protocol for one (N, sigma, algorithm, format)
    cell; ``fmt`` selects the posit format of the whole solve path."""
    if algo == "cholesky":
        a64 = make_spd(n, sigma, seed)
    elif algo == "lu":
        a64 = make_general(n, sigma, seed)
    else:
        raise ValueError(algo)
    x_sol = np.full((n,), 1.0 / np.sqrt(n))
    b64 = a64 @ x_sol

    # posit path
    a_p = posit.from_float64(jnp.asarray(a64), fmt)
    b_p = posit.from_float64(jnp.asarray(b64), fmt)
    if algo == "cholesky":
        l_p = decomp.rpotrf(a_p, nb=nb, gemm_backend=gemm_backend, fmt=fmt)
        xhat_p = solve.rpotrs(l_p, b_p, fmt=fmt)
    else:
        lu_p, ipiv = decomp.rgetrf(a_p, nb=nb, gemm_backend=gemm_backend,
                                   fmt=fmt)
        xhat_p = solve.rgetrs(lu_p, ipiv, b_p, fmt=fmt)
    xhat64 = np.asarray(posit.to_float64(xhat_p, fmt))
    e_posit = _backward_error(a64, xhat64, b64)

    # binary32 path
    a32 = jnp.asarray(a64, jnp.float32)
    b32 = jnp.asarray(b64, jnp.float32)
    if algo == "cholesky":
        l32 = decomp.spotrf(a32)
        xhat32 = solve.spotrs(l32, b32)
    else:
        lu32, piv = decomp.sgetrf(a32)
        xhat32 = solve.sgetrs(lu32, piv, b32)
    e_b32 = _backward_error(a64, np.asarray(xhat32, np.float64), b64)

    return ErrorResult(n=n, sigma=sigma, algo=algo, e_posit=e_posit,
                       e_binary32=e_b32, fmt=fmt.name)


# --------------------------------------------------------------------------
# batched ensemble protocol — many (sigma, seed) cells as ONE program
# --------------------------------------------------------------------------

def backward_error_ensemble(n: int, sigmas, algo: str = "lu", seeds=(0, 1),
                            nb: int = 32, gemm_backend: str = "xla_quire",
                            fmt: PositFormat = P32E2) -> list[ErrorResult]:
    """The §5.1 protocol over a (sigma x seed) grid, batched: every posit
    factorization in the grid runs inside ONE ``rpotrf_batched`` /
    ``rgetrf_batched`` dispatch (decomp.py), and the triangular solves are
    vmapped over the same axis — the "many matrices x many phi scales"
    ensemble as a single batched program instead of a Python grid sweep.
    Per-cell *posit* results are bit-identical to ``backward_error_study``
    run with the SAME ``gemm_backend`` (vmapping the posit programs
    changes no rounding; pinned in tests/test_perf_paths.py).  The
    binary32 baseline may differ at f32-rounding level: XLA's batched
    LU/Cholesky kernels are not bit-identical to their single-matrix
    forms.  Note the defaults differ:
    ``backward_error_study`` defaults to the paper's per-MAC 'faithful'
    PE for Fig. 7 fidelity, while the batched ensemble defaults to the
    fast 'xla_quire' path — pass ``gemm_backend`` explicitly to compare
    cells across the two drivers.
    """
    sigmas = list(sigmas)
    seeds = list(seeds)
    make = make_spd if algo == "cholesky" else make_general
    if algo not in ("cholesky", "lu"):
        raise ValueError(algo)
    cells = [(s, sd) for s in sigmas for sd in seeds]
    a64 = np.stack([make(n, s, sd) for s, sd in cells])
    x_sol = np.full((n,), 1.0 / np.sqrt(n))
    b64 = a64 @ x_sol

    a_p = posit.from_float64(jnp.asarray(a64), fmt)
    b_p = posit.from_float64(jnp.asarray(b64), fmt)
    if algo == "cholesky":
        l_p = decomp.rpotrf_batched(a_p, nb=nb, gemm_backend=gemm_backend,
                                    fmt=fmt)
        xhat_p = jax.vmap(lambda l, b: solve.rpotrs(l, b, fmt=fmt))(l_p, b_p)
    else:
        lu_p, ipiv = decomp.rgetrf_batched(a_p, nb=nb,
                                           gemm_backend=gemm_backend,
                                           fmt=fmt)
        xhat_p = jax.vmap(lambda lu, pv, b: solve.rgetrs(lu, pv, b, fmt=fmt)
                          )(lu_p, ipiv, b_p)
    xhat64 = np.asarray(posit.to_float64(xhat_p, fmt))

    a32 = jnp.asarray(a64, jnp.float32)
    b32 = jnp.asarray(b64, jnp.float32)
    if algo == "cholesky":
        l32 = jax.vmap(decomp.spotrf)(a32)
        xhat32 = jax.vmap(solve.spotrs)(l32, b32)
    else:
        lu32, piv = jax.vmap(decomp.sgetrf)(a32)
        xhat32 = jax.vmap(solve.sgetrs)(lu32, piv, b32)
    xhat32 = np.asarray(xhat32, np.float64)

    out = []
    for i, (s, sd) in enumerate(cells):
        out.append(ErrorResult(
            n=n, sigma=s, algo=algo,
            e_posit=_backward_error(a64[i], xhat64[i], b64[i]),
            e_binary32=_backward_error(a64[i], xhat32[i], b64[i]),
            fmt=fmt.name))
    return out


# --------------------------------------------------------------------------
# beyond-paper: quire iterative refinement vs plain posit solve
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RefineResult:
    n: int
    sigma: float
    algo: str
    iters: int
    e_plain: float      # plain Rgetrs/Rpotrs from the same factorization
    e_ir: float         # after quire-exact iterative refinement

    @property
    def digits_gained(self) -> float:
        """Decimal digits of backward error recovered by refinement."""
        return float(np.log10(self.e_plain / max(self.e_ir, 1e-300)))


def refinement_study(n: int, sigma: float = 1.0, algo: str = "lu",
                     seed: int = 0, nb: int = 32, iters: int = 3,
                     gemm_backend: str = "xla_quire") -> RefineResult:
    """§5.1 protocol (phi=0 ensemble: sigma=1) comparing the plain posit
    solve against rgesv_ir/rposv_ir from the SAME factorization.

    Backward errors here are measured against the posit-held (A, b) the
    solver was actually given (decoded exactly to binary64) — the
    textbook definition of a *solver's* backward error.  The one-time
    posit32 input-quantization error (~2^-28, which would otherwise
    floor BOTH columns) is a property of the protocol, not the solver,
    and is already what ``backward_error_study`` reports."""
    if algo == "cholesky":
        a64 = make_spd(n, sigma, seed)
    elif algo == "lu":
        a64 = make_general(n, sigma, seed)
    else:
        raise ValueError(algo)
    x_sol = np.full((n,), 1.0 / np.sqrt(n))
    b64 = a64 @ x_sol

    a_p = posit.from_float64(jnp.asarray(a64))
    b_p = posit.from_float64(jnp.asarray(b64))
    a64q = np.asarray(posit.to_float64(a_p))     # exact decode: the problem
    b64q = np.asarray(posit.to_float64(b_p))     # the solver actually sees
    if algo == "cholesky":
        (x_hi, x_lo), l_p = refine.rposv_ir(a_p, b_p, iters=iters, nb=nb,
                                            gemm_backend=gemm_backend)
        x_plain = solve.rpotrs(l_p, b_p)
    else:
        (x_hi, x_lo), (lu, ipiv) = refine.rgesv_ir(a_p, b_p, iters=iters,
                                                   nb=nb,
                                                   gemm_backend=gemm_backend)
        x_plain = solve.rgetrs(lu, ipiv, b_p)

    e_plain = _backward_error(a64q, np.asarray(posit.to_float64(x_plain)),
                              b64q)
    e_ir = _backward_error(a64q,
                           np.asarray(refine.pair_to_float64(x_hi, x_lo)),
                           b64q)
    return RefineResult(n=n, sigma=sigma, algo=algo, iters=iters,
                        e_plain=e_plain, e_ir=e_ir)


# --------------------------------------------------------------------------
# mixed-precision IR vs full-width IR on the §5.1 sigma grid
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MixedPrecisionResult:
    n: int
    sigma: float
    algo: str
    e_ir: float         # full-width (p32e2) factorization + refinement
    e_mp: float         # narrow (factor_fmt) factorization + p32e2 refinement
    factor_fmt: str = "p16e1"

    @property
    def digits_lost(self) -> float:
        """Decimal digits of backward error the narrow factorization costs
        AFTER refinement (~0 wherever the mp loop converges — the
        acceptance criterion bench_formats.py gates on)."""
        return float(np.log10(max(self.e_mp, 1e-300)
                              / max(self.e_ir, 1e-300)))


def make_rect(m: int, n: int, sigma: float, seed: int = 0) -> np.ndarray:
    """A = X with X ~ N(0, sigma), (m, n) over-determined — the §5.1
    ensemble extended to the least-squares scenario.  Rectangular
    Gaussians are well conditioned (cond ~ (sqrt(m)+sqrt(n)) /
    (sqrt(m)-sqrt(n))), so the sigma sweep isolates the golden-zone
    scale effect rather than conditioning."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, n)) * sigma


@dataclasses.dataclass
class LeastSquaresResult:
    m: int
    n: int
    sigma: float
    e_qr: float         # plain rgels (QR + back-substitution)
    e_ir: float         # rgels_ir (quire-exact CSNE refinement)
    e_mp: float         # rgels_mp (narrow factor + working-fmt refinement)
    e_opt: float        # the f64 lstsq optimum on the SAME posit-held data
    e_binary32: float   # sgels baseline
    factor_fmt: str = "p16e1"

    @property
    def digits(self) -> float:
        """Plain posit QR vs binary32 (paper Fig. 7 convention)."""
        return float(np.log10(self.e_binary32 / self.e_qr))

    @property
    def digits_gained(self) -> float:
        """Decimal digits of backward error the refinement recovers."""
        return float(np.log10(self.e_qr / max(self.e_ir, 1e-300)))

    @property
    def digits_from_opt(self) -> float:
        """Distance of the refined solve from the true LS optimum of the
        posit-held problem (~0 == the refinement attained the minimum).
        Unlike the square studies, the over-determined floor is NOT the
        pair rounding: quantizing (A, b) to posit words makes the f64-
        consistent system inconsistent, so even the exact LS solution
        keeps a residual ~ ||b|| * eps_posit — ``e_opt`` is that floor,
        and the refined iterate should sit on it."""
        return float(np.log10(max(self.e_ir, 1e-300)
                              / max(self.e_opt, 1e-300)))

    @property
    def digits_lost(self) -> float:
        """Digits the narrow factorization costs AFTER refinement (~0
        wherever the mp loop converges — the bench_qr.py gate)."""
        return float(np.log10(max(self.e_mp, 1e-300)
                              / max(self.e_ir, 1e-300)))


def least_squares_study(m: int, n: int, sigma: float = 1.0, seed: int = 0,
                        nb: int = 16, iters_ir: int = 3,
                        iters_mp: int | None = None,
                        gemm_backend: str = "xla_quire"
                        ) -> LeastSquaresResult:
    """The §5.1 protocol on the over-determined scenario: x_sol =
    (1/sqrt(n)) ones, b = A x_sol in binary64 (a consistent system, so
    the relative residual IS the backward error, as in the square
    studies), solved four ways — plain ``rgels``, quire-refined
    ``rgels_ir``, mixed-precision ``rgels_mp``, binary32 ``sgels``.

    Posit backward errors are measured against the posit-held (A, b) the
    solvers actually see (the ``refinement_study`` convention); the
    binary32 error against the f64 originals (the
    ``backward_error_study`` convention for the cross-format column).
    """
    a64 = make_rect(m, n, sigma, seed)
    x_sol = np.full((n,), 1.0 / np.sqrt(n))
    b64 = a64 @ x_sol

    a_p = posit.from_float64(jnp.asarray(a64))
    b_p = posit.from_float64(jnp.asarray(b64))
    a64q = np.asarray(posit.to_float64(a_p))
    b64q = np.asarray(posit.to_float64(b_p))

    x_plain, _ = qr.rgels(a_p, b_p, nb=nb, gemm_backend=gemm_backend)
    (h_ir, l_ir), _ = qr.rgels_ir(a_p, b_p, iters=iters_ir, nb=nb,
                                  gemm_backend=gemm_backend)
    mp_kw = {} if iters_mp is None else {"iters": iters_mp}
    (h_mp, l_mp), _ = qr.rgels_mp(a_p, b_p, nb=nb,
                                  gemm_backend=gemm_backend, **mp_kw)
    e_qr = _backward_error(a64q, np.asarray(posit.to_float64(x_plain)),
                           b64q)
    e_ir = _backward_error(a64q,
                           np.asarray(refine.pair_to_float64(h_ir, l_ir)),
                           b64q)
    e_mp = _backward_error(a64q,
                           np.asarray(refine.pair_to_float64(h_mp, l_mp)),
                           b64q)
    x_opt = np.linalg.lstsq(a64q, b64q, rcond=None)[0]
    e_opt = _backward_error(a64q, x_opt, b64q)
    x32 = qr.sgels(jnp.asarray(a64, jnp.float32),
                   jnp.asarray(b64, jnp.float32))
    e_b32 = _backward_error(a64, np.asarray(x32, np.float64), b64)
    return LeastSquaresResult(m=m, n=n, sigma=sigma, e_qr=e_qr, e_ir=e_ir,
                              e_mp=e_mp, e_opt=e_opt, e_binary32=e_b32)


def mixed_precision_study(n: int, sigma: float = 1.0, algo: str = "lu",
                          seed: int = 0, nb: int = 32, iters_ir: int = 3,
                          iters_mp: int | None = None,
                          gemm_backend: str = "xla_quire"
                          ) -> MixedPrecisionResult:
    """§5.1 protocol comparing ``rgesv_mp``/``rposv_mp`` (p16e1 factor +
    p32e2 quire refinement) against ``rgesv_ir``/``rposv_ir`` (full-width
    factor) on the same (A, b) cell.  Both backward errors are measured
    against the p32e2-held problem the solvers actually see (the same
    convention as ``refinement_study``).  Wherever the mp contraction
    converges (cond(A) * eps_p16e1 < 1) the two errors land on the same
    posit-pair floor — digits_lost ~ 0 — while the mp factorization is
    the measurably cheaper one (benchmarks/bench_formats.py).
    ``iters_mp=None`` uses each driver's default (8 LU / 16 Cholesky —
    the SPD ensemble's squared condition number halves the per-sweep
    contraction)."""
    if algo == "cholesky":
        a64 = make_spd(n, sigma, seed)
    elif algo == "lu":
        a64 = make_general(n, sigma, seed)
    else:
        raise ValueError(algo)
    x_sol = np.full((n,), 1.0 / np.sqrt(n))
    b64 = a64 @ x_sol

    a_p = posit.from_float64(jnp.asarray(a64))
    b_p = posit.from_float64(jnp.asarray(b64))
    a64q = np.asarray(posit.to_float64(a_p))
    b64q = np.asarray(posit.to_float64(b_p))
    mp_kw = {} if iters_mp is None else {"iters": iters_mp}
    if algo == "cholesky":
        (h_ir, l_ir), _ = refine.rposv_ir(a_p, b_p, iters=iters_ir, nb=nb,
                                          gemm_backend=gemm_backend)
        (h_mp, l_mp), _ = refine.rposv_mp(a_p, b_p, nb=nb,
                                          gemm_backend=gemm_backend, **mp_kw)
    else:
        (h_ir, l_ir), _ = refine.rgesv_ir(a_p, b_p, iters=iters_ir, nb=nb,
                                          gemm_backend=gemm_backend)
        (h_mp, l_mp), _ = refine.rgesv_mp(a_p, b_p, nb=nb,
                                          gemm_backend=gemm_backend, **mp_kw)
    e_ir = _backward_error(a64q, np.asarray(refine.pair_to_float64(h_ir,
                                                                   l_ir)),
                           b64q)
    e_mp = _backward_error(a64q, np.asarray(refine.pair_to_float64(h_mp,
                                                                   l_mp)),
                           b64q)
    return MixedPrecisionResult(n=n, sigma=sigma, algo=algo, e_ir=e_ir,
                                e_mp=e_mp)


# --------------------------------------------------------------------------
# golden-zone occupancy vs accuracy (the obs-layer study)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class GoldenZoneResult:
    """One §5.1 sigma cell annotated with repro.obs telemetry: where the
    operand words sit relative to the format's golden zone, and what
    that cost/bought in digits."""
    n: int
    sigma: float
    algo: str
    fmt: str
    occupancy: float        # golden-zone fraction of A's posit words
    e_plain: float          # plain Rgetrs/Rpotrs backward error
    e_ir: float             # after quire-exact refinement
    e_binary32: float       # f32 LAPACK baseline
    sweeps: list = dataclasses.field(default_factory=list)  # ir.sweep rows

    @property
    def digits(self) -> float:
        """Plain posit solve vs binary32 (paper Fig. 7 convention)."""
        return float(np.log10(self.e_binary32 / self.e_plain))

    @property
    def digits_gained(self) -> float:
        return float(np.log10(self.e_plain / max(self.e_ir, 1e-300)))


def golden_zone_study(n: int, sigmas, algo: str = "lu", seed: int = 0,
                      nb: int = 32, iters: int = 3,
                      gemm_backend: str = "xla_quire",
                      fmt: PositFormat = P32E2) -> list[GoldenZoneResult]:
    """The §5.1 sigma sweep with the observability layer ON: each cell
    records A's golden-zone occupancy (fraction of words with regime
    exponent k in {0, -1} — where ``fmt`` keeps its maximal fraction
    width), the plain/refined/binary32 backward errors, and the
    ``ir.sweep`` per-iteration convergence rows.  The paper's Fig. 7
    "accuracy depends on operand scale" effect, with the mechanism made
    measurable: digits-vs-binary32 tracks occupancy as sigma walks the
    operands out of the golden zone."""
    out = []
    for sigma in sigmas:
        if algo == "cholesky":
            a64 = make_spd(n, sigma, seed)
        elif algo == "lu":
            a64 = make_general(n, sigma, seed)
        else:
            raise ValueError(algo)
        x_sol = np.full((n,), 1.0 / np.sqrt(n))
        b64 = a64 @ x_sol
        a_p = posit.from_float64(jnp.asarray(a64), fmt)
        b_p = posit.from_float64(jnp.asarray(b64), fmt)
        a64q = np.asarray(posit.to_float64(a_p, fmt))
        b64q = np.asarray(posit.to_float64(b_p, fmt))

        with obs.scoped() as m:
            if algo == "cholesky":
                (x_hi, x_lo), l_p = refine.rposv_ir(
                    a_p, b_p, iters=iters, nb=nb,
                    gemm_backend=gemm_backend, fmt=fmt)
                x_plain = solve.rpotrs(l_p, b_p, fmt=fmt)
            else:
                (x_hi, x_lo), (lu, ipiv) = refine.rgesv_ir(
                    a_p, b_p, iters=iters, nb=nb,
                    gemm_backend=gemm_backend, fmt=fmt)
                x_plain = solve.rgetrs(lu, ipiv, b_p, fmt=fmt)
        sweeps = m.to_dict()["series"].get("ir.sweep", [])

        e_plain = _backward_error(
            a64q, np.asarray(posit.to_float64(x_plain, fmt)), b64q)
        e_ir = _backward_error(
            a64q, np.asarray(refine.pair_to_float64(x_hi, x_lo, fmt)), b64q)
        a32 = jnp.asarray(a64, jnp.float32)
        b32 = jnp.asarray(b64, jnp.float32)
        if algo == "cholesky":
            xhat32 = solve.spotrs(decomp.spotrf(a32), b32)
        else:
            lu32, piv = decomp.sgetrf(a32)
            xhat32 = solve.sgetrs(lu32, piv, b32)
        e_b32 = _backward_error(a64, np.asarray(xhat32, np.float64), b64)

        out.append(GoldenZoneResult(
            n=n, sigma=float(sigma), algo=algo, fmt=fmt.name,
            occupancy=obs.golden_zone_fraction(a_p, fmt),
            e_plain=e_plain, e_ir=e_ir, e_binary32=e_b32, sweeps=sweeps))
    return out


def golden_zone_table(results: list[GoldenZoneResult]) -> str:
    """Markdown table of a ``golden_zone_study`` sweep + the occupancy/
    digits correlation line (what the nightly CI appends to its step
    summary)."""
    lines = ["| sigma | golden-zone occupancy | digits vs b32 | "
             "IR digits gained | sweeps |",
             "|---|---|---|---|---|"]
    for r in results:
        lines.append(f"| {r.sigma:g} | {r.occupancy:.3f} | {r.digits:+.2f} |"
                     f" {r.digits_gained:+.2f} | {len(r.sweeps)} |")
    if len(results) >= 3:
        occ = np.asarray([r.occupancy for r in results])
        dig = np.asarray([r.digits for r in results])
        if occ.std() > 0 and dig.std() > 0:
            rho = float(np.corrcoef(occ, dig)[0, 1])
            lines.append(f"\noccupancy/digits correlation: r = {rho:+.3f} "
                         f"({len(results)} cells)")
    return "\n".join(lines)
