from repro.data.pipeline import make_batch, input_specs

__all__ = ["make_batch", "input_specs"]
