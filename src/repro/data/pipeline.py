"""Deterministic synthetic data pipeline.

Batches are pure functions of (seed, step): a restarted job regenerates an
identical stream from any step — the data-side half of fault tolerance
(checkpoint/restore is the other half).  Token statistics follow a Zipfian
marginal so embedding-gather locality is realistic rather than uniform.

``input_specs`` produces ShapeDtypeStruct stand-ins for every model input
of a (config x shape-cell) pair — the dry-run lowers against these, no
allocation (spec: MULTI-POD DRY-RUN item 2).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeCell
from repro.models.common import ArchConfig


def _zipf_tokens(key, shape, vocab: int):
    """Zipf-ish marginal over the vocab via inverse-CDF of u^alpha."""
    u = jax.random.uniform(key, shape, dtype=jnp.float32, minval=1e-6)
    r = jnp.power(u, jnp.float32(4.0))            # heavy head
    ids = (r * vocab).astype(jnp.int32)
    return jnp.clip(ids, 0, vocab - 1)


def make_batch(cfg: ArchConfig, cell: ShapeCell, step: int, seed: int = 0,
               batch_override: int | None = None) -> dict[str, Any]:
    """Materialize one global batch (smoke/e2e runs use small overrides)."""
    b = batch_override or cell.global_batch
    s = cell.seq_len
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = _zipf_tokens(k1, (b, s), cfg.vocab)
    targets = jnp.concatenate(
        [tokens[:, 1:], _zipf_tokens(k2, (b, 1), cfg.vocab)], axis=1)
    batch = {"tokens": tokens, "targets": targets}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k3, (b, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["vis"] = jax.random.normal(
            k3, (b, cfg.vis_tokens, cfg.d_model), jnp.float32) * 0.1
    return batch


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the dry-run (no device allocation)."""
    b, s = cell.global_batch, cell.seq_len
    f32 = jnp.float32
    if cell.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq,
                                                    cfg.d_model), f32)
        if cfg.family == "vlm":
            specs["vis"] = jax.ShapeDtypeStruct((b, cfg.vis_tokens,
                                                 cfg.d_model), f32)
        return specs
    # decode: one incoming token + absolute position
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
