"""Distributed-memory posit linear algebra (ScaLAPACK flavor, mesh-native).

The paper runs Rpotrf/Rgetrf in Posit(32,2) on ONE accelerator; this
subsystem distributes the part that scales — the trailing-update Rgemms
and the quire residuals — over a P x Q device grid while keeping every
output word **bit-identical** to the single-device routines (the posit
determinism story: controlled accumulation order survives distribution
because the quire's cross-device reduction is exact integer limb adds).

    layout.py   2D block-cyclic DistMatrix over make_grid_mesh(p, q)
    pblas.py    pdgemm (SUMMA owner-computes / quire limb-psum K-split)
                + p_residual_quire (distributed exact IR residual)
    pdecomp.py  p_rpotrf / p_rgetrf / p_rgesv_ir / p_rposv_ir

Everything runs hermetically on CPU host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the tier-1
path — and unchanged on real TPU meshes.  See DESIGN.md §7.
"""
from repro.dist.layout import (BlockCyclic, DistMatrix, distribute,
                               gather_array, make_grid_mesh, scatter_array)
from repro.dist.pblas import p_residual_quire, pdgemm
from repro.dist.pdecomp import (p_rgesv_ir, p_rgetrf, p_rposv_ir, p_rpotrf)

__all__ = [
    "BlockCyclic", "DistMatrix", "distribute", "scatter_array",
    "gather_array", "make_grid_mesh",
    "pdgemm", "p_residual_quire",
    "p_rpotrf", "p_rgetrf", "p_rgesv_ir", "p_rposv_ir",
]
