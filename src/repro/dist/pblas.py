"""PBLAS over posit words: SUMMA-style distributed Rgemm (+ the quire
matrix-vector residual the IR solvers reduce across devices).

``pdgemm`` computes C = alpha * A @ B + beta * C with A (M, K), B (K, N),
C (M, N) block-cyclic over the P x Q grid (dist/layout.py), per-device
products running through the ordinary ``kernels.ops.rgemm`` backends.
Two schedules, both **bit-identical to single-device rgemm** (the
acceptance contract, pinned in tests/test_dist.py and asserted by
benchmarks/bench_dist.py before any speedup is reported):

* **owner-computes** (default): one all_gather of A's row strip along
  "col" and of B's column strip along "row" (the batched form of SUMMA's
  per-panel broadcasts), then ONE local ``rgemm`` over the full K on the
  C-tile owner.  Every output element is produced by the same backend
  from the same full-K row/column vectors as on a single device, so the
  result is elementwise identical for EVERY backend — including the f32-
  and f64-accumulating ones whose partial sums would not re-associate.
  Compute per device is (M/P)(N/Q)K — perfect O(PQ) scaling of the
  multiply work; memory is the ScaLAPACK panel bound O((M/P + N/Q) K).

* **k_split** (quire backend only): each device deposits its LOCAL K
  slab into int64 quire limb planes (``quire.quire_gemm_limbs``, the
  pre-rounding hook) for all N output columns in dist column order; the
  cross-device reduction is a ``psum_scatter`` of those integer planes
  across "col" — each device receives exactly its own tile's limbs —
  and the single posit rounding happens after it.  Bit-identical to
  single-device ``quire_gemm`` *by construction* (integer limb adds are
  associative; no float partial-sum scheme can say this).  This is the
  deep-K schedule: A never moves (each device consumes its own K slab —
  owner-computes gathers O(lm * K) A words per device), B moves by
  slab-exchange all_to_all (O(K * N / Q), not replication), and the
  price is the O(lm * Q*ln * L) limb-plane scatter-reduce — worth it
  when K >> N * L, i.e. deep reductions with narrow outputs.  The IR
  residual (N = nrhs, x already replicated so NOTHING is gathered) is
  exactly that shape; it uses the plain-psum form
  (``launch.collectives.limb_psum``) since its output has no column
  partition.

``p_residual_quire`` is the K-split path specialized to the refinement
residual r = b - A (x + x_lo): one exact fused dot per row, deposited
across the grid's column axis, psum-reduced in limb space, rounded once —
the distributed drop-in for ``lapack.refine.residual_quire``.

``fmt`` (static, default Posit(32,2)) selects the posit format of every
word on the grid: the owner-computes schedule threads it straight into
the local ``rgemm`` (so any format any backend), and the k_split limb
planes take their limb count from the format's quire (4 limbs for
p16e1/p8e2 vs 16 for p32e2 — the psum payload shrinks 4x, same
bit-identity argument).  One format per call; mixed-format distributed
GEMM converts at the boundary like the single-device path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import P32E2, PositFormat
from repro.core import posit
from repro.kernels.ops import rgemm
from repro.launch.collectives import limb_psum
from repro.launch.compat import shard_map
from repro.obs import metrics as _obs_metrics
from repro.obs import numerics as _obs_numerics
from repro.obs import trace as _obs_trace
from repro.quire import (Quire, q_to_posit, qadd_posit, quire_gemm_limbs,
                         quire_limbs)
from repro.dist.layout import (BlockCyclic, DistMatrix, grid_coords,
                               local_gidx, unshuffle)

_SPEC = jax.sharding.PartitionSpec("row", "col")
_REP = jax.sharding.PartitionSpec()


def _gather_rows_fullK(a_loc, lay_a: BlockCyclic):
    """(lm, lk) local tile of A -> (lm, K) full-K rows for this device's
    block-cyclic rows: all_gather A's column strip along "col" and
    unpermute the cyclic column order."""
    g = jax.lax.all_gather(a_loc.T, "col", tiled=False)   # (Q, lk, lm)
    return unshuffle(g, lay_a.q, lay_a.nb).T[:, :lay_a.n]


def _gather_cols_fullK(b_loc, lay_b: BlockCyclic):
    """(lk, ln) local tile of B -> (K, ln) full-K columns."""
    g = jax.lax.all_gather(b_loc, "row", tiled=False)     # (P, lk, ln)
    return unshuffle(g, lay_b.p, lay_b.nb)[:lay_b.m]


def _dist_col_order(lay: BlockCyclic):
    """Static global-column index for every dist-order column position
    (c', t, v) -> (c' + Q*t)*nb + v; padding positions map past n."""
    idx = []
    for cp in range(lay.q):
        for t in range(lay.lnb):
            base = (cp + lay.q * t) * lay.nb
            idx.extend(range(base, base + lay.nb))
    return jnp.asarray(idx, jnp.int32)


def _k_slab_limbs(a_loc, b_loc, lay_a: BlockCyclic, lay_b: BlockCyclic,
                  negate: bool, fmt: PositFormat = P32E2):
    """Split-K deposit: this device's K slab (A's local columns, global
    k ≡ this grid column mod Q) against ALL N output columns, arranged
    in dist column order.  The (lm, Q*ln, L) limb planes reduce across
    "col" with ONE psum_scatter — integer limb adds, so the merged state
    is bit-identical to a single-device full-K deposit — and the scatter
    hands each device back exactly its own (lm, ln, L) tile.

    B movement is slab-exchange, not replication: gather my columns'
    full K along "row" (O(K * ln) words), regroup the K rows into the Q
    cyclic slabs (static permutation), then ONE all_to_all along "col" —
    each device ends holding only its (lk, N) slab, O(K * N / Q) words.
    """
    _, c = grid_coords()
    b_full = _gather_cols_fullK(b_loc, lay_b)             # (K, ln)
    # pad + permute K rows into dist-slab order (slab c' = rows k ≡ c'
    # mod Q, each of length lk = lay_a.ln); padding rows masked to the
    # zero word so they deposit nothing and can't poison nar.
    kslab = _dist_col_order(lay_a)                        # (Q*lk,) static
    b_slabs = jnp.where((kslab < lay_a.n)[:, None],
                        b_full[jnp.clip(kslab, 0, lay_b.m - 1)], 0)
    # slab exchange: send slab c' of my columns to device c'; receive my
    # slab from every column peer -> (lk, Q*ln), columns grouped by
    # source = exactly dist column order.
    b_dist = jax.lax.all_to_all(b_slabs, "col", split_axis=0, concat_axis=1,
                                tiled=True)
    limbs, nar = quire_gemm_limbs(a_loc, b_dist, fmt, negate=negate)
    limbs = jax.lax.psum_scatter(limbs, "col", scatter_dimension=1,
                                 tiled=True)              # (lm, ln, L)
    nar = jax.lax.psum_scatter(nar.astype(jnp.int32), "col",
                               scatter_dimension=1, tiled=True) > 0
    return limbs, nar


def _pdgemm_local(a_loc, b_loc, c_loc, lay_a, lay_b, alpha, beta,
                  backend, k_split, fmt: PositFormat = P32E2):
    if k_split:
        if backend != "quire_exact":
            raise ValueError("k_split pdgemm is the quire limb-plane "
                             "schedule; use backend='quire_exact'")
        a_in = a_loc
        if alpha not in (1.0, -1.0, 1, -1):
            alpha_p = posit.from_float64(jnp.float64(alpha), fmt)
            a_in = posit.mul(alpha_p, a_loc, fmt, backend="fast")
        limbs, nar = _k_slab_limbs(a_in, b_loc, lay_a, lay_b,
                                   negate=alpha in (-1.0, -1), fmt=fmt)
        q = Quire(limbs=limbs, nar=nar)
        if beta in (1.0, 1):
            q = qadd_posit(q, c_loc, fmt)
        elif beta not in (0.0, 0):
            beta_p = posit.from_float64(jnp.float64(beta), fmt)
            q = qadd_posit(q, posit.mul(beta_p, c_loc, fmt, backend="fast"),
                           fmt)
        return q_to_posit(q, fmt)
    a_full = _gather_rows_fullK(a_loc, lay_a)             # (lm, K)
    b_full = _gather_cols_fullK(b_loc, lay_b)             # (K, ln)
    return rgemm(a_full, b_full, c_loc, alpha=alpha, beta=beta,
                 backend=backend, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("lay_a", "lay_b", "mesh",
                                             "alpha", "beta", "backend",
                                             "k_split", "fmt"))
def _pdgemm_sharded(a, b, c, *, lay_a, lay_b, mesh, alpha, beta,
                    backend, k_split, fmt):
    fn = functools.partial(_pdgemm_local, lay_a=lay_a, lay_b=lay_b,
                           alpha=alpha, beta=beta,
                           backend=backend, k_split=k_split, fmt=fmt)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC, _SPEC, _SPEC),
                     out_specs=_SPEC, check_vma=False)(a, b, c)


def pdgemm_collective_plan(lay_a: BlockCyclic, lay_b: BlockCyclic,
                           k_split: bool = False,
                           fmt: PositFormat = P32E2) -> dict[str, int]:
    """Static PER-DEVICE collective byte plan of one ``pdgemm`` dispatch:
    {collective kind -> result bytes}, derived purely from the layouts.
    Same accounting convention as ``launch.hlo_analysis.collective_bytes``
    (sum of per-device collective RESULT shapes in the SPMD module), so
    the two are directly comparable — ``benchmarks/roofline.py
    --check-pdgemm`` asserts they and the runtime obs counters agree.

    owner-computes: A row strip gathered along "col" ((Q, lk, lm) i32)
    + B column strip along "row" ((P, lk, ln) i32).  k_split: B strip
    gather, the (lk, Q*ln) i32 slab-exchange all_to_all, and the
    (lm, ln, L) i64 + (lm, ln) i32 limb-plane reduce-scatter pair.
    """
    if not k_split:
        return {"all-gather": 4 * (lay_a.q * lay_a.ln * lay_a.lm
                                   + lay_b.p * lay_b.lm * lay_b.ln)}
    lay_c = BlockCyclic(m=lay_a.m, n=lay_b.n, nb=lay_a.nb,
                        p=lay_a.p, q=lay_a.q)
    L = quire_limbs(fmt)
    return {
        "all-gather": 4 * lay_b.p * lay_b.lm * lay_b.ln,
        "all-to-all": 4 * lay_a.ln * lay_a.q * lay_b.ln,
        "reduce-scatter": lay_c.lm * lay_c.ln * (8 * L + 4),
    }


def p_residual_plan(lay: BlockCyclic, nrhs: int = 1,
                    fmt: PositFormat = P32E2) -> dict[str, int]:
    """Static PER-DEVICE collective byte plan of one ``p_residual_quire``
    dispatch (same convention as ``pdgemm_collective_plan``): the
    (lm, nrhs, L) i64 + (lm, nrhs) i32 limb psum (all-reduce) and the
    (P, lm, nrhs) i32 row gather of the rounded residual."""
    L = quire_limbs(fmt)
    return {
        "all-reduce": lay.lm * nrhs * (8 * L + 4),
        "all-gather": 4 * lay.p * lay.lm * nrhs,
    }


def _record_collectives(name: str, plan: dict[str, int]) -> None:
    """Counter per collective kind: ``name.<kind>.bytes`` (per-device)."""
    for kind, nbytes in plan.items():
        _obs_metrics.inc(f"{name}.{kind}.bytes", nbytes)
    _obs_metrics.inc(f"{name}.calls")


def pdgemm(a: DistMatrix, b: DistMatrix, c: DistMatrix | None = None,
           alpha=1.0, beta=0.0, backend: str = "xla_quire",
           k_split: bool = False, fmt: PositFormat = P32E2) -> DistMatrix:
    """Distributed C = alpha * A @ B + beta * C, one jitted dispatch.

    ``backend`` is any ``rgemm`` backend; ``k_split=True`` selects the
    quire limb-plane psum schedule (quire_exact only).  ``fmt`` is the
    posit format of every word (both schedules; the owner-computes
    schedule simply hands it to the local ``rgemm``).  The result is
    bit-identical to single-device ``rgemm`` with the same ``fmt`` on
    the gathered operands in either schedule.
    """
    la, lb = a.layout, b.layout
    if (la.n, la.nb, la.p, la.q) != (lb.m, lb.nb, lb.p, lb.q):
        raise ValueError(f"incompatible layouts {la} @ {lb}")
    lay_c = BlockCyclic(m=la.m, n=lb.n, nb=la.nb, p=la.p, q=la.q)
    if c is None:
        sharding = jax.sharding.NamedSharding(a.mesh, _SPEC)
        c_data = jnp.zeros((lay_c.p * lay_c.lm, lay_c.q * lay_c.ln),
                           jnp.int32)
        c_data = jax.device_put(c_data, sharding)
    else:
        if c.layout != lay_c:
            raise ValueError(f"C layout {c.layout} != {lay_c}")
        c_data = c.data
    if _obs_numerics.active(a.data, b.data, c_data):
        with _obs_trace.span("pdgemm", m=la.m, k=la.n, n=lb.n,
                             grid=f"{la.p}x{la.q}", backend=backend,
                             k_split=k_split, fmt=fmt.name):
            out = _pdgemm_sharded(a.data, b.data, c_data, lay_a=la, lay_b=lb,
                                  mesh=a.mesh, alpha=alpha, beta=beta,
                                  backend=backend, k_split=k_split, fmt=fmt)
        _record_collectives("dist.pdgemm",
                            pdgemm_collective_plan(la, lb, k_split=k_split,
                                                   fmt=fmt))
        _obs_numerics.record_numerics("dist.pdgemm.out", out, fmt)
    else:
        out = _pdgemm_sharded(a.data, b.data, c_data, lay_a=la, lay_b=lb,
                              mesh=a.mesh, alpha=alpha, beta=beta,
                              backend=backend, k_split=k_split, fmt=fmt)
    return DistMatrix(data=out, layout=lay_c, mesh=a.mesh)


# --------------------------------------------------------------------------
# distributed quire residual (matrix-vector / multi-RHS K-split)
# --------------------------------------------------------------------------

def _residual_local(a_loc, x, b, x_lo, lay: BlockCyclic,
                    fmt: PositFormat = P32E2):
    """r = b - A (x + x_lo), one exact fused dot per row, K split across
    the grid columns and reduced in limb space; output replicated."""
    r_, c = grid_coords()
    kidx = local_gidx(lay, 1, c)                          # (lk,)
    valid = (kidx < lay.n)[:, None]
    kc = jnp.clip(kidx, 0, lay.n - 1)
    x_sel = jnp.where(valid, x[kc], 0)                    # (lk, nrhs)
    if x_lo is None:
        a2, x2 = a_loc, x_sel
    else:
        # the pair residual b - A*hi - A*lo as ONE fused reduction: the
        # same [A | A] @ [hi; lo] concatenation as residual_quire, with
        # the K halves living on the same device slab.
        lo_sel = jnp.where(valid, x_lo[kc], 0)
        a2 = jnp.concatenate([a_loc, a_loc], axis=1)
        x2 = jnp.concatenate([x_sel, lo_sel], axis=0)
    limbs, nar = quire_gemm_limbs(a2, x2, fmt, negate=True)
    limbs, nar = limb_psum(limbs, nar, "col")
    gidx = local_gidx(lay, 0, r_)                         # (lm,)
    rvalid = (gidx < lay.m)[:, None]
    b_my = jnp.where(rvalid, b[jnp.clip(gidx, 0, lay.m - 1)], 0)
    q = Quire(limbs=limbs, nar=nar & rvalid)
    q = qadd_posit(q, b_my, fmt)
    r_rows = q_to_posit(q, fmt)                           # (lm, nrhs)
    full = unshuffle(jax.lax.all_gather(r_rows, "row", tiled=False),
                     lay.p, lay.nb)                       # (P*lm, nrhs)
    return full[:lay.m]


@functools.partial(jax.jit, static_argnames=("lay", "mesh", "pair", "fmt"))
def _residual_sharded(a, x, b, x_lo, *, lay, mesh, pair, fmt):
    fn = lambda ad, xd, bd, ld: _residual_local(ad, xd, bd,
                                                ld if pair else None, lay,
                                                fmt)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC, _REP, _REP, _REP),
                     out_specs=_REP, check_vma=False)(a, x, b, x_lo)


def p_residual_quire(a: DistMatrix, x_p: jax.Array, b_p: jax.Array,
                     x_lo_p: jax.Array | None = None,
                     fmt: PositFormat = P32E2) -> jax.Array:
    """Distributed drop-in for ``lapack.refine.residual_quire``: each
    component of r = b - A (x + x_lo) is an exact fused dot product
    rounded ONCE, with the K reduction psum-ed across the grid in int64
    limb planes — bit-identical to the single-device quire residual by
    limb-add associativity.  x/b replicated (n,) or (n, nrhs); returns
    the replicated residual of the same shape."""
    lay = a.layout
    x_p = jnp.asarray(x_p, jnp.int32)
    b_p = jnp.asarray(b_p, jnp.int32)
    vec = x_p.ndim == 1
    x2 = x_p[:, None] if vec else x_p
    b2 = b_p[:, None] if vec else b_p
    pair = x_lo_p is not None
    lo2 = (jnp.asarray(x_lo_p, jnp.int32)[:, None] if vec
           else jnp.asarray(x_lo_p, jnp.int32)) if pair else jnp.zeros_like(x2)
    if _obs_numerics.active(a.data, x2, b2, lo2):
        with _obs_trace.span("p_residual", n=lay.n, nrhs=int(x2.shape[1]),
                             grid=f"{lay.p}x{lay.q}", fmt=fmt.name):
            r = _residual_sharded(a.data, x2, b2, lo2, lay=lay, mesh=a.mesh,
                                  pair=pair, fmt=fmt)
        _record_collectives("dist.p_residual",
                            p_residual_plan(lay, nrhs=int(x2.shape[1]),
                                            fmt=fmt))
    else:
        r = _residual_sharded(a.data, x2, b2, lo2, lay=lay, mesh=a.mesh,
                              pair=pair, fmt=fmt)
    return r[:, 0] if vec else r


# --------------------------------------------------------------------------
# checksum-protected distributed GEMM (exact ABFT, repro.ft — DESIGN.md §11)
# --------------------------------------------------------------------------

def _pdgemm_ft_local(a_loc, b_loc, c_loc, *, lay_a, lay_b, alpha, beta,
                     backend, fmt, plan, active):
    """Owner-computes pdgemm with both operand gathers carrying exact
    checksum strips: A's per-row and B's per-column value sums are
    deposited from the LOCAL tiles (zero words in the padding deposit
    nothing) and psum-reduced across the axis the gather spans — limb
    adds are associative, so the strip equals the checksum of the
    gathered full-K operand exactly.  Every device then recomputes the
    checksums of the operands it actually received and compares exactly;
    the conjunction psums grid-wide.  Injection sites 'pdgemm.a' /
    'pdgemm.b' corrupt one device's gathered copy (dev = r*Q + c)."""
    from repro.ft import abft
    from repro.quire.quire import Quire, q_renorm
    r, c = grid_coords()
    dev = r * lay_a.q + c
    al, anar = abft._word_limbs(a_loc, fmt)               # (lm, lk, L)
    arow = jax.lax.psum(jnp.sum(al, axis=1), "col")       # (lm, L)
    arow_nar = jax.lax.psum(jnp.sum(anar.astype(jnp.int32), axis=1),
                            "col") > 0
    arow_w = jax.lax.psum(jnp.sum(a_loc.astype(jnp.int64), axis=1), "col")
    qa = q_renorm(Quire(limbs=arow, nar=arow_nar))
    bl, bnar = abft._word_limbs(b_loc, fmt)               # (lk, ln, L)
    bcol = jax.lax.psum(jnp.sum(bl, axis=0), "row")       # (ln, L)
    bcol_nar = jax.lax.psum(jnp.sum(bnar.astype(jnp.int32), axis=0),
                            "row") > 0
    bcol_w = jax.lax.psum(jnp.sum(b_loc.astype(jnp.int64), axis=0), "row")
    qb = q_renorm(Quire(limbs=bcol, nar=bcol_nar))

    a_full = _gather_rows_fullK(a_loc, lay_a)             # (lm, K)
    b_full = _gather_cols_fullK(b_loc, lay_b)             # (K, ln)
    if active and plan is not None:
        a_full = plan.words("pdgemm.a", 0, a_full, fmt, dev=dev)
        b_full = plan.words("pdgemm.b", 0, b_full, fmt, dev=dev)
    ga, ga_nar = abft.word_sums(a_full, fmt, axis=1)
    gb, gb_nar = abft.word_sums(b_full, fmt, axis=0)
    ok = (jnp.all(ga == qa.limbs) & jnp.all(ga_nar == qa.nar)
          & jnp.all(jnp.sum(a_full.astype(jnp.int64), axis=1) == arow_w)
          & jnp.all(gb == qb.limbs) & jnp.all(gb_nar == qb.nar)
          & jnp.all(jnp.sum(b_full.astype(jnp.int64), axis=0) == bcol_w))
    okc = jax.lax.psum(jax.lax.psum(ok.astype(jnp.int32), "col"), "row")
    out = rgemm(a_full, b_full, c_loc, alpha=alpha, beta=beta,
                backend=backend, fmt=fmt)
    return out, okc


@functools.partial(jax.jit, static_argnames=("lay_a", "lay_b", "mesh",
                                             "alpha", "beta", "backend",
                                             "fmt", "plan", "active"))
def _pdgemm_ft_sharded(a, b, c, *, lay_a, lay_b, mesh, alpha, beta,
                       backend, fmt, plan, active):
    fn = functools.partial(_pdgemm_ft_local, lay_a=lay_a, lay_b=lay_b,
                           alpha=alpha, beta=beta, backend=backend, fmt=fmt,
                           plan=plan, active=active)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC, _SPEC, _SPEC),
                     out_specs=(_SPEC, _REP), check_vma=False)(a, b, c)


def pdgemm_ft(a: DistMatrix, b: DistMatrix, c: DistMatrix | None = None,
              alpha=1.0, beta=0.0, backend: str = "xla_quire",
              fmt: PositFormat = P32E2, plan=None, max_retries: int = 2):
    """Checksum-protected owner-computes ``pdgemm``: returns
    (C DistMatrix, FtReport), C bit-identical to ``pdgemm`` fault-free
    and after recovery.  A failed grid-wide verify re-dispatches the
    whole GEMM (gathers are the unit of recovery here — the k_split
    limb-plane schedule is already integrity-checked end to end by the
    repo's bit-identity contract and has no gathered replica to
    corrupt, so it has no _ft variant).  Exhaustion raises
    ``AbftError`` (repro.ft.abft)."""
    from repro import ft
    la, lb = a.layout, b.layout
    if (la.n, la.nb, la.p, la.q) != (lb.m, lb.nb, lb.p, lb.q):
        raise ValueError(f"incompatible layouts {la} @ {lb}")
    lay_c = BlockCyclic(m=la.m, n=lb.n, nb=la.nb, p=la.p, q=la.q)
    if c is None:
        sharding = jax.sharding.NamedSharding(a.mesh, _SPEC)
        c_data = jnp.zeros((lay_c.p * lay_c.lm, lay_c.q * lay_c.ln),
                           jnp.int32)
        c_data = jax.device_put(c_data, sharding)
    else:
        if c.layout != lay_c:
            raise ValueError(f"C layout {c.layout} != {lay_c}")
        c_data = c.data
    report = ft.FtReport()
    for attempt in range(max_retries + 1):
        out, okc = _pdgemm_ft_sharded(a.data, b.data, c_data, lay_a=la,
                                      lay_b=lb, mesh=a.mesh, alpha=alpha,
                                      beta=beta, backend=backend, fmt=fmt,
                                      plan=plan, active=(attempt == 0))
        if int(okc) == la.p * la.q:
            report.retries = attempt
            return DistMatrix(data=out, layout=lay_c, mesh=a.mesh), report
        report.detections += 1
        report.sites.append(("pdgemm", 0))
        _obs_metrics.inc("ft.detections")
        _obs_metrics.inc("ft.retries")
    report.failed = True
    from repro.ft.abft import AbftError
    raise AbftError(f"pdgemm_ft: gather mismatch persisted across "
                    f"{max_retries + 1} attempts")
