"""2D block-cyclic layouts for distributed posit matrices (ScaLAPACK
descriptor, mesh-native).

A posit matrix is int32 words, so a distributed posit matrix is an int32
plane sharded over a P x Q ("row", "col") process grid
(``launch.mesh.make_grid_mesh``).  Global block (bi, bj) — ``nb x nb``
posit words — is owned by device (bi mod P, bj mod Q) and stored at local
block (bi // P, bj // Q):

        global blocks                device (r, c) local tiles
      bj:  0    1    2    3            holds bi ≡ r (mod P),
    bi 0  0,0  0,1  0,0  0,1                 bj ≡ c (mod Q)
       1  1,0  1,1  1,0  1,1        e.g. P=Q=2, device (0,1):
       2  0,0  0,1  0,0  0,1             blocks (0,1) (0,3)
       3  1,0  1,1  1,0  1,1                    (2,1) (2,3)

Cyclic assignment keeps every device busy through a right-looking
factorization: as the trailing matrix shrinks, surviving blocks stay
spread over the whole grid instead of draining to one corner (the reason
ScaLAPACK block-cyclic exists).

**Representation.**  The distributed value is ONE jax.Array of shape
(P * lm, Q * ln) — device (r, c)'s (lm, ln) local tile sits at rows
[r*lm, (r+1)*lm) — sharded contiguously by ``PartitionSpec("row",
"col")``.  That makes the dist array a row/column *permutation* of the
zero-padded global matrix, so scatter/gather are pure index math
(``scatter_array`` / ``gather_array``), identical on host numpy and
traced values.  Padding blocks hold posit word 0 (value 0); by
construction they are the HIGHEST-indexed global blocks, so gather is a
plain slice after unpermuting.

Device-side helpers (used inside shard_map, where the device coordinate
is a traced ``axis_index``): ``local_gidx`` (global index of every local
row/col), ``unshuffle`` (axis-gathered tiles -> global order), and
``select_block_col`` (masked read of one global block column).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.launch.compat import axis_index  # also installs shard_map shim
from repro.launch.mesh import make_grid_mesh

__all__ = ["BlockCyclic", "DistMatrix", "distribute", "scatter_array",
           "gather_array", "local_gidx", "unshuffle", "select_block_col",
           "grid_coords", "make_grid_mesh"]


@dataclasses.dataclass(frozen=True)
class BlockCyclic:
    """Layout descriptor: (m, n) global posit matrix, nb x nb blocks,
    P x Q grid.  Hashable — usable as a jit static argument."""
    m: int
    n: int
    nb: int
    p: int
    q: int

    @property
    def mb(self) -> int:                     # global block rows
        return -(-self.m // self.nb)

    @property
    def nbk(self) -> int:                    # global block cols
        return -(-self.n // self.nb)

    @property
    def lmb(self) -> int:                    # local block rows per device
        return -(-self.mb // self.p)

    @property
    def lnb(self) -> int:                    # local block cols per device
        return -(-self.nbk // self.q)

    @property
    def lm(self) -> int:                     # local rows per device
        return self.lmb * self.nb

    @property
    def ln(self) -> int:                     # local cols per device
        return self.lnb * self.nb

    def block_owner(self, bi: int, bj: int) -> tuple[int, int]:
        return bi % self.p, bj % self.q

    def col_block_home(self, j: int) -> tuple[int, int, int]:
        """Global column j -> (owner grid column, local block col index,
        offset within the local tile).  Static math for panel schedules."""
        bj = j // self.nb
        return bj % self.q, bj // self.q, (bj // self.q) * self.nb + j % self.nb


def _perm(g: int, blocks: int, lb: int):
    """Dist-order block index list: position (grid coord r, local t) holds
    global block r + g*t... i.e. entry k = (k // lb) + g * (k % lb)."""
    return [(k // lb) + g * (k % lb) for k in range(g * lb)]


def scatter_array(x, lay: BlockCyclic):
    """Replicated (m, n) posit words -> (P*lm, Q*ln) dist array (pure
    index permutation + zero padding; jnp, so it traces)."""
    x = jnp.asarray(x, jnp.int32)
    assert x.shape == (lay.m, lay.n), (x.shape, lay)
    pad_r, pad_c = lay.p * lay.lm - lay.m, lay.q * lay.ln - lay.n
    x = jnp.pad(x, ((0, pad_r), (0, pad_c)))
    t = x.reshape(lay.p * lay.lmb, lay.nb, lay.q * lay.lnb, lay.nb)
    bi = jnp.asarray(_perm(lay.p, lay.mb, lay.lmb))
    bj = jnp.asarray(_perm(lay.q, lay.nbk, lay.lnb))
    return t[bi][:, :, bj].reshape(lay.p * lay.lm, lay.q * lay.ln)


def gather_array(d, lay: BlockCyclic):
    """(P*lm, Q*ln) dist array -> replicated (m, n) posit words (inverse
    of ``scatter_array``)."""
    d = jnp.asarray(d)
    t = d.reshape(lay.p, lay.lmb, lay.nb, lay.q, lay.lnb, lay.nb)
    # dist block (r, t) holds global block r + P*t: ascending global order
    # is (t outer, r inner); padding blocks land at the end of each axis.
    g = t.transpose(1, 0, 2, 4, 3, 5).reshape(lay.p * lay.lm,
                                              lay.q * lay.ln)
    return g[:lay.m, :lay.n]


@dataclasses.dataclass
class DistMatrix:
    """A block-cyclic distributed posit matrix: the sharded int32 plane
    plus its layout and mesh.  ``data`` rows/cols are in dist (device-
    major) order — use ``gather()`` for the global-order matrix."""
    data: jax.Array
    layout: BlockCyclic
    mesh: jax.sharding.Mesh

    @property
    def shape(self):
        return (self.layout.m, self.layout.n)

    @property
    def spec(self):
        return jax.sharding.PartitionSpec("row", "col")

    def gather(self) -> jax.Array:
        return gather_array(self.data, self.layout)

    def with_data(self, data: jax.Array) -> "DistMatrix":
        return DistMatrix(data=data, layout=self.layout, mesh=self.mesh)


def distribute(x, mesh: jax.sharding.Mesh, nb: int = 32) -> DistMatrix:
    """Scatter a replicated (m, n) posit-word matrix onto the mesh."""
    p, q = mesh.shape["row"], mesh.shape["col"]
    x = jnp.asarray(x, jnp.int32)
    lay = BlockCyclic(m=x.shape[0], n=x.shape[1], nb=nb, p=p, q=q)
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("row", "col"))
    data = jax.device_put(scatter_array(x, lay), sharding)
    return DistMatrix(data=data, layout=lay, mesh=mesh)


# --------------------------------------------------------------------------
# device-side index math (inside shard_map; grid coordinate is traced)
# --------------------------------------------------------------------------

def local_gidx(lay: BlockCyclic, axis: int, coord):
    """Global row (axis=0) / column (axis=1) index of every local row/col
    on the device at traced grid coordinate ``coord``: local position
    t*nb + u maps to global (coord + g*t)*nb + u.  Padding rows/cols map
    past m/n — callers mask with ``< lay.m`` / ``< lay.n``."""
    g, lb = ((lay.p, lay.lmb) if axis == 0 else (lay.q, lay.lnb))
    t = jnp.arange(lb, dtype=jnp.int32)
    u = jnp.arange(lay.nb, dtype=jnp.int32)
    return ((coord + g * t[:, None]) * lay.nb + u[None, :]).reshape(-1)


def unshuffle(gathered: jax.Array, g: int, nb: int) -> jax.Array:
    """(g, lb*nb, ...) axis-0 ``all_gather`` of local tiles -> (g*lb*nb,
    ...) rows in GLOBAL order (gathered[r', t] holds global block
    r' + g*t, so ascending order is t-major)."""
    lb = gathered.shape[1] // nb
    t = gathered.reshape((g, lb, nb) + gathered.shape[2:])
    t = jnp.moveaxis(t, 0, 1)
    return t.reshape((g * lb * nb,) + gathered.shape[2:])


def select_block_col(a_loc: jax.Array, lay: BlockCyclic, coord, j: int,
                     w: int) -> jax.Array:
    """Masked read of global columns [j, j+w) from a local tile: the
    owner grid column returns its (lm, w) slice, everyone else zeros —
    so a psum over "col" broadcasts the panel to the whole grid row.
    ``j`` is static (block schedule); ``coord`` is the traced grid
    column.  Requires the panel not to straddle a block boundary
    (j % nb + w <= nb, the LAPACK panel shape)."""
    c_star, _, off = lay.col_block_home(j)
    assert j % lay.nb + w <= lay.nb, (j, w, lay.nb)
    sl = jax.lax.slice_in_dim(a_loc, off, off + w, axis=1)
    return jnp.where(jnp.asarray(coord == c_star), sl, 0)


def grid_coords():
    """Traced (row, col) coordinate of the executing device."""
    return axis_index("row"), axis_index("col")
