"""Distributed right-looking Rpotrf / Rgetrf + IR solvers over the grid.

ScaLAPACK's pdpotrf/pdgetrf schedule, expressed as ONE shard_map-jitted
XLA program per factorization (the dist analogue of PR 2's single-
dispatch drivers — the block schedule is static at trace time, the
device coordinate is the only traced index):

per block step j (width w = min(nb, n - j)):
  1. **panel broadcast** — the owning grid column's (lm, w) slice is
     psum-selected across "col" (non-owners contribute zero words), then
     all_gather'd + unpermuted along "row": every device holds the
     replicated (m, w) panel column.
  2. **panel factorization, replicated** — ``potf2`` / ``getf2`` run
     identically on every device (same words in, same words out; XLA CPU
     is bitwise deterministic), standing in for ScaLAPACK's column-team
     factor-then-broadcast with zero extra schedule states.
  3. (LU) **pivot application** — ``getf2``'s w swaps compose into one
     net row permutation (computed on the replicated ipiv); each device
     re-gathers its rows from the "row"-axis all_gather of its column
     strip through that permutation — one collective for the whole
     panel's swaps.
  4. **trailing update, distributed** — each device updates its OWN
     block-cyclic tiles with one local ``rgemm`` (any backend): the
     replicated panel is gathered per-device into (lm, w) / (ln, w)
     operand rows/cols by traced global index, and the masked write
     keeps only trailing-region elements.  Per-element this is the SAME
     backend reduction over the same K = w operands as the single-device
     trailing rgemm, so words match bit-for-bit (quire backends by limb
     associativity; f32/f64 backends by elementwise determinism of the
     fixed-K reduction — both pinned in tests/test_dist.py).

The masked update computes a full (lm, ln) tile product each step
(Σ_j lm*ln*w ≈ n³/(PQ) MACs vs the single-device Σ (n-j)²w ≈ n³/3) —
the uniform-SPMD trade: no data-dependent shapes, every device does
identical work, and the 3x constant is recovered once P*Q >= 3.

``p_rgesv_ir`` / ``p_rposv_ir`` wire the distributed pieces into
``lapack.refine.refine_pair``: distributed factorization, replicated
quire substitution sweeps on the gathered factors (O(n²) — not worth
distributing), and **distributed residuals** (``pblas.p_residual_quire``,
limb-plane psum) — bit-identical end to end to ``rgesv_ir``/``rposv_ir``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import P32E2
from repro.kernels.ops import rgemm
from repro.lapack import solve
from repro.lapack.blas import rtrsm_left_lower, rtrsm_right_lowerT
from repro.lapack.decomp import getf2, potf2
from repro.lapack.refine import refine_pair
from repro.launch.compat import shard_map
from repro.obs import metrics as _obs_metrics
from repro.obs import numerics as _obs_numerics
from repro.obs import trace as _obs_trace
from repro.dist.layout import (BlockCyclic, DistMatrix, grid_coords,
                               local_gidx, select_block_col, unshuffle)
from repro.dist.pblas import _record_collectives, p_residual_quire

_FMT = P32E2
_SPEC = jax.sharding.PartitionSpec("row", "col")
_REP = jax.sharding.PartitionSpec()


def _replicate_panel(a_loc, lay: BlockCyclic, c, j: int, w: int):
    """Steps 1 of the schedule: the (m, w) global column panel [*, j:j+w)
    replicated on every device (psum-select across "col", gather along
    "row", unpermute)."""
    mine = select_block_col(a_loc, lay, c, j, w)          # (lm, w) or 0
    rows = jax.lax.psum(mine, "col")                      # (lm, w)
    full = unshuffle(jax.lax.all_gather(rows, "row", tiled=False),
                     lay.p, lay.nb)
    return full[:lay.m]                                   # (m, w)


def _write_panel(a_loc, lay: BlockCyclic, r, c, j: int, w: int, col_new,
                 row_lo: int):
    """Masked write of replicated (m, w) ``col_new`` into the owner grid
    column's local tiles, rows [row_lo, m)."""
    c_star, _, off = lay.col_block_home(j)
    gidx = local_gidx(lay, 0, r)
    mine = col_new[jnp.clip(gidx, 0, lay.m - 1)]          # (lm, w)
    mask = ((c == c_star) & (gidx >= row_lo) & (gidx < lay.m))[:, None]
    cur = jax.lax.slice_in_dim(a_loc, off, off + w, axis=1)
    return jax.lax.dynamic_update_slice_in_dim(
        a_loc, jnp.where(mask, mine, cur), off, axis=1)


def _rpotrf_local(a_loc, lay: BlockCyclic, gemm_backend: str):
    n, nb = lay.n, lay.nb
    r, c = grid_coords()
    gr = local_gidx(lay, 0, r)                            # (lm,)
    gc = local_gidx(lay, 1, c)                            # (ln,)
    for j in range(0, n, nb):
        w = min(nb, n - j)
        colpan = _replicate_panel(a_loc, lay, c, j, w)    # (m, w)
        l11 = potf2(colpan[j:j + w])
        if j + w < n:
            a21 = rtrsm_right_lowerT(colpan[j + w:], l11)
            lcol = jnp.concatenate([colpan[:j], l11, a21])
        else:
            lcol = jnp.concatenate([colpan[:j], l11])
        a_loc = _write_panel(a_loc, lay, r, c, j, w, lcol, row_lo=j)
        if j + w < n:
            ar = lcol[jnp.clip(gr, 0, n - 1)]             # (lm, w)
            ac = lcol[jnp.clip(gc, 0, n - 1)]             # (ln, w)
            upd = rgemm(ar, ac, a_loc, alpha=-1.0, beta=1.0, trans_b=True,
                        backend=gemm_backend)
            tmask = (((gr >= j + w) & (gr < n))[:, None]
                     & ((gc >= j + w) & (gc < n))[None, :])
            a_loc = jnp.where(tmask, upd, a_loc)
    # zero the strict upper triangle and the padding region (word 0 == 0)
    keep = (gr[:, None] >= gc[None, :]) & (gr < n)[:, None] & (gc < n)[None, :]
    return jnp.where(keep, a_loc, 0)


def _rgetrf_local(a_loc, lay: BlockCyclic, gemm_backend: str):
    m, n, nb = lay.m, lay.n, lay.nb
    mn = min(m, n)
    r, c = grid_coords()
    gr = local_gidx(lay, 0, r)
    gc = local_gidx(lay, 1, c)
    ipiv = jnp.zeros((mn,), jnp.int32)
    for j in range(0, mn, nb):
        w = min(nb, mn - j)
        colpan = _replicate_panel(a_loc, lay, c, j, w)    # (m, w)
        pan, piv_loc = getf2(colpan[j:], w)               # replicated
        ipiv = jax.lax.dynamic_update_slice_in_dim(
            ipiv, piv_loc + j, j, axis=0)
        # net permutation of the w swaps (rows j..m), applied to every
        # column strip through ONE "row"-axis gather
        idx = jnp.arange(m, dtype=jnp.int32)
        for k in range(w):
            rk = j + k
            rp = j + piv_loc[k]
            vk, vp = idx[rk], idx[rp]
            idx = idx.at[rk].set(vp).at[rp].set(vk)
        strip = unshuffle(jax.lax.all_gather(a_loc, "row", tiled=False),
                          lay.p, lay.nb)[:m]              # (m, ln)
        strip = strip[idx]
        swapped = strip[jnp.clip(gr, 0, m - 1)]           # (lm, ln)
        a_loc = jnp.where(((gr >= j) & (gr < m))[:, None], swapped, a_loc)
        # factored panel (already internally swapped) overwrites its column
        pcol = jnp.concatenate([colpan[:j], pan]) if j else pan
        a_loc = _write_panel(a_loc, lay, r, c, j, w, pcol, row_lo=j)
        if j + w < n:
            # U12 row block: per-column unit-lower solve on MY columns of
            # the post-swap rows [j, j+w)
            u12 = rtrsm_left_lower(pan[:w], strip[j:j + w], unit_diag=True)
            u12_mine = u12[jnp.clip(gr - j, 0, w - 1)]    # (lm, ln)
            rmask = ((gr >= j) & (gr < j + w))[:, None]
            cmask = ((gc >= j + w) & (gc < n))[None, :]
            a_loc = jnp.where(rmask & cmask, u12_mine, a_loc)
            if j + w < m:
                l21 = pan[jnp.clip(gr - j, 0, m - j - 1)]  # (lm, w)
                upd = rgemm(l21, u12, a_loc, alpha=-1.0, beta=1.0,
                            backend=gemm_backend)
                tmask = (((gr >= j + w) & (gr < m))[:, None]
                         & ((gc >= j + w) & (gc < n))[None, :])
                a_loc = jnp.where(tmask, upd, a_loc)
    keep = (gr < m)[:, None] & (gc < n)[None, :]
    return jnp.where(keep, a_loc, 0), ipiv


@functools.partial(jax.jit, static_argnames=("lay", "mesh", "gemm_backend"))
def _p_rpotrf_sharded(a, *, lay, mesh, gemm_backend):
    fn = functools.partial(_rpotrf_local, lay=lay, gemm_backend=gemm_backend)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC,), out_specs=_SPEC,
                     check_vma=False)(a)


@functools.partial(jax.jit, static_argnames=("lay", "mesh", "gemm_backend"))
def _p_rgetrf_sharded(a, *, lay, mesh, gemm_backend):
    fn = functools.partial(_rgetrf_local, lay=lay, gemm_backend=gemm_backend)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC,),
                     out_specs=(_SPEC, _REP), check_vma=False)(a)


def pfactor_collective_plan(lay: BlockCyclic,
                            algo: str = "getrf") -> dict[str, int]:
    """Static PER-DEVICE collective byte plan of one distributed blocked
    factorization (``pblas.pdgemm_collective_plan`` convention).  Per
    block step: the (lm, w) i32 panel psum-select (all-reduce) and its
    (P, lm, w) i32 row gather; LU adds the per-step (P, lm, ln) i32
    column-strip gather the net pivot permutation reads through."""
    if algo not in ("getrf", "potrf"):
        raise ValueError(f"unknown algo {algo!r}")
    mn = min(lay.m, lay.n) if algo == "getrf" else lay.n
    ar = ag = 0
    for j in range(0, mn, lay.nb):
        w = min(lay.nb, mn - j)
        ar += 4 * lay.lm * w
        ag += 4 * lay.p * lay.lm * w
        if algo == "getrf":
            ag += 4 * lay.p * lay.lm * lay.ln
    return {"all-reduce": ar, "all-gather": ag}


def p_rpotrf(a: DistMatrix, gemm_backend: str = "xla_quire",
             checkpoint_dir=None, resume: bool = False) -> DistMatrix:
    """Distributed blocked lower Cholesky; bit-identical words to
    ``lapack.rpotrf(gather(a), nb=a.layout.nb, gemm_backend=...)``.  The
    block size IS the layout block size (the ScaLAPACK coupling: the
    algorithmic and distribution blockings coincide).

    With ``checkpoint_dir`` set, the factorization runs host-stepped
    through ``p_rpotrf_ft`` (same words — pinned in tests/test_dist.py)
    saving per-panel checkpoints, and ``resume=True`` restarts from the
    newest saved step bit-identically.  Default (no checkpointing)
    dispatches the unchanged single-program path."""
    lay = a.layout
    if lay.m != lay.n:
        raise ValueError(f"Cholesky needs square A, got {a.shape}")
    if checkpoint_dir is not None:
        out, _ = p_rpotrf_ft(a, gemm_backend=gemm_backend,
                             checkpoint_dir=checkpoint_dir, resume=resume)
        return out
    if _obs_numerics.active(a.data):
        with _obs_trace.span("p_rpotrf", n=lay.n, nb=lay.nb,
                             grid=f"{lay.p}x{lay.q}", backend=gemm_backend):
            out = _p_rpotrf_sharded(a.data, lay=lay, mesh=a.mesh,
                                    gemm_backend=gemm_backend)
        _record_collectives("dist.rpotrf",
                            pfactor_collective_plan(lay, algo="potrf"))
        _obs_numerics.record_numerics("dist.rpotrf.out", out, _FMT)
    else:
        out = _p_rpotrf_sharded(a.data, lay=lay, mesh=a.mesh,
                                gemm_backend=gemm_backend)
    return a.with_data(out)


def p_rgetrf(a: DistMatrix, gemm_backend: str = "xla_quire",
             checkpoint_dir=None, resume: bool = False):
    """Distributed blocked partial-pivot LU; returns (LU DistMatrix,
    replicated ipiv) bit-identical to ``lapack.rgetrf`` at nb =
    a.layout.nb.  ``checkpoint_dir``/``resume`` select the host-stepped
    per-panel checkpointing path (see ``p_rpotrf``)."""
    lay = a.layout
    if checkpoint_dir is not None:
        lu, ipiv, _ = p_rgetrf_ft(a, gemm_backend=gemm_backend,
                                  checkpoint_dir=checkpoint_dir,
                                  resume=resume)
        return lu, ipiv
    if _obs_numerics.active(a.data):
        with _obs_trace.span("p_rgetrf", m=lay.m, n=lay.n, nb=lay.nb,
                             grid=f"{lay.p}x{lay.q}", backend=gemm_backend):
            lu, ipiv = _p_rgetrf_sharded(a.data, lay=lay, mesh=a.mesh,
                                         gemm_backend=gemm_backend)
        _record_collectives("dist.rgetrf",
                            pfactor_collective_plan(lay, algo="getrf"))
        _obs_numerics.record_numerics("dist.rgetrf.out", lu, _FMT)
    else:
        lu, ipiv = _p_rgetrf_sharded(a.data, lay=lay, mesh=a.mesh,
                                     gemm_backend=gemm_backend)
    return a.with_data(lu), ipiv


# --------------------------------------------------------------------------
# distributed iterative-refinement drivers
# --------------------------------------------------------------------------

def _p_driver(a: DistMatrix, b_p, solve_fn, iters: int):
    """refine_pair over columns with DISTRIBUTED residuals.  RHS columns
    loop in Python (nrhs is small and the factorization — the O(n³)
    part — is already amortized across them)."""
    b_p = jnp.asarray(b_p, jnp.int32)
    residual_fn = lambda hi, lo, b: p_residual_quire(a, hi, b, lo)
    if b_p.ndim == 1:
        return refine_pair(solve_fn, residual_fn, b_p, iters)
    cols = [refine_pair(solve_fn, residual_fn, b_p[:, i], iters)
            for i in range(b_p.shape[1])]
    return (jnp.stack([h for h, _ in cols], axis=1),
            jnp.stack([l for _, l in cols], axis=1))


def p_rgesv_ir(a: DistMatrix, b_p, iters: int = 3,
               gemm_backend: str = "xla_quire"):
    """Distributed LU solve of A x = b with quire-exact iterative
    refinement: ``p_rgetrf`` factorization, replicated quire substitution
    sweeps on the gathered LU, and distributed limb-psum residuals.
    Returns ((x_hi, x_lo), (lu DistMatrix, ipiv)) with the pair words
    bit-identical to ``lapack.rgesv_ir`` at nb = a.layout.nb."""
    lu, ipiv = p_rgetrf(a, gemm_backend=gemm_backend)
    lu_rep = lu.gather()
    solve_fn = lambda r: solve.rgetrs(lu_rep, ipiv, r, quire=True)
    return _p_driver(a, b_p, solve_fn, iters), (lu, ipiv)


def p_rposv_ir(a: DistMatrix, b_p, iters: int = 3,
               gemm_backend: str = "xla_quire"):
    """Distributed Cholesky SPD solve with quire-exact iterative
    refinement; same conventions as ``p_rgesv_ir``.  Returns
    ((x_hi, x_lo), l DistMatrix)."""
    l_d = p_rpotrf(a, gemm_backend=gemm_backend)
    l_rep = l_d.gather()
    solve_fn = lambda r: solve.rpotrs(l_rep, r, quire=True)
    return _p_driver(a, b_p, solve_fn, iters), l_d


# --------------------------------------------------------------------------
# checksum-protected distributed drivers + per-panel checkpoint/restart
# (exact ABFT, repro.ft — DESIGN.md §11)
# --------------------------------------------------------------------------
#
# Host-stepped analogues of _rpotrf_local/_rgetrf_local: one shard_map
# dispatch per block step, where the panel BROADCAST carries its checksum
# strip.  The strip is computed from the pre-broadcast owner slices —
# each device deposits its local words into quire limbs and the strips
# psum across BOTH grid axes, so by limb-add associativity the strip is
# the exact column checksum of the panel no matter how it is sharded.
# After the broadcast every device recomputes the checksum of the
# replicated panel it actually RECEIVED and compares exactly; the
# conjunction psums across the grid, so one corrupted replica anywhere
# fails the step on every device, and the host retries it — panel
# re-broadcast + local recompute from the verified pre-step state, not a
# full restart.  Injection site "dist.panel" (device-gated via the
# linear id r*Q + c) corrupts one device's received replica, which is
# the broadcast-fault model: the wire is fine, a receiver's buffer
# flipped.

def _strip_cks(mine, fmt=_FMT):
    """Exact per-column checksums of a broadcast panel from its
    PRE-broadcast owner slices ``mine`` ((lm, w), zero off-owner):
    (canonical (w, L) value-sum limbs, (w,) nar, (w,) raw word sums),
    psum-reduced over both grid axes."""
    from repro.ft import abft
    from repro.quire.quire import Quire, q_renorm
    limbs, nar = abft._word_limbs(mine, fmt)
    lsum = jax.lax.psum(jax.lax.psum(jnp.sum(limbs, axis=0), "col"), "row")
    nsum = jax.lax.psum(jax.lax.psum(
        jnp.sum(nar.astype(jnp.int32), axis=0), "col"), "row") > 0
    wsum = jax.lax.psum(jax.lax.psum(
        jnp.sum(mine.astype(jnp.int64), axis=0), "col"), "row")
    q = q_renorm(Quire(limbs=lsum, nar=nsum))
    return q.limbs, q.nar, wsum


def _strip_verify(colpan, srow, snar, swsum, fmt=_FMT):
    """Per-device exact recompute-and-compare of the received replica
    against the strip; returns the grid-wide count of agreeing devices
    (== P*Q iff every replica verified)."""
    from repro.ft import abft
    grow, gnar = abft.word_sums(colpan, fmt, axis=0)
    gw = jnp.sum(colpan.astype(jnp.int64), axis=0)
    ok = (jnp.all(grow == srow) & jnp.all(gnar == snar)
          & jnp.all(gw == swsum))
    return jax.lax.psum(jax.lax.psum(ok.astype(jnp.int32), "col"), "row")


def _replicate_panel_ft(a_loc, lay: BlockCyclic, r, c, j: int, w: int,
                        plan, active: bool):
    """_replicate_panel with the checksum strip riding the broadcast and
    the 'dist.panel' injection window on the received replica."""
    mine = select_block_col(a_loc, lay, c, j, w)
    srow, snar, swsum = _strip_cks(mine)
    rows = jax.lax.psum(mine, "col")
    full = unshuffle(jax.lax.all_gather(rows, "row", tiled=False),
                     lay.p, lay.nb)
    colpan = full[:lay.m]
    if active and plan is not None:
        colpan = plan.words("dist.panel", j // lay.nb, colpan, _FMT,
                            dev=r * lay.q + c)
    okc = _strip_verify(colpan, srow, snar, swsum)
    return colpan, okc


def _rpotrf_ft_step_local(a_loc, *, lay: BlockCyclic, j: int,
                          gemm_backend: str, plan, active: bool):
    """One _rpotrf_local block step (same per-j ops) with the verified
    broadcast; returns (a_loc', agreeing-device count)."""
    n, nb = lay.n, lay.nb
    r, c = grid_coords()
    gr = local_gidx(lay, 0, r)
    gc = local_gidx(lay, 1, c)
    w = min(nb, n - j)
    colpan, okc = _replicate_panel_ft(a_loc, lay, r, c, j, w, plan, active)
    l11 = potf2(colpan[j:j + w])
    if j + w < n:
        a21 = rtrsm_right_lowerT(colpan[j + w:], l11)
        lcol = jnp.concatenate([colpan[:j], l11, a21])
    else:
        lcol = jnp.concatenate([colpan[:j], l11])
    a_loc = _write_panel(a_loc, lay, r, c, j, w, lcol, row_lo=j)
    if j + w < n:
        ar = lcol[jnp.clip(gr, 0, n - 1)]
        ac = lcol[jnp.clip(gc, 0, n - 1)]
        upd = rgemm(ar, ac, a_loc, alpha=-1.0, beta=1.0, trans_b=True,
                    backend=gemm_backend)
        tmask = (((gr >= j + w) & (gr < n))[:, None]
                 & ((gc >= j + w) & (gc < n))[None, :])
        a_loc = jnp.where(tmask, upd, a_loc)
    return a_loc, okc


def _rgetrf_ft_step_local(a_loc, ipiv, *, lay: BlockCyclic, j: int,
                          gemm_backend: str, plan, active: bool):
    """One _rgetrf_local block step (same per-j ops) with the verified
    broadcast; returns (a_loc', ipiv', agreeing-device count)."""
    m, n, nb = lay.m, lay.n, lay.nb
    mn = min(m, n)
    r, c = grid_coords()
    gr = local_gidx(lay, 0, r)
    gc = local_gidx(lay, 1, c)
    w = min(nb, mn - j)
    colpan, okc = _replicate_panel_ft(a_loc, lay, r, c, j, w, plan, active)
    pan, piv_loc = getf2(colpan[j:], w)
    ipiv = jax.lax.dynamic_update_slice_in_dim(ipiv, piv_loc + j, j, axis=0)
    idx = jnp.arange(m, dtype=jnp.int32)
    for k in range(w):
        rk = j + k
        rp = j + piv_loc[k]
        vk, vp = idx[rk], idx[rp]
        idx = idx.at[rk].set(vp).at[rp].set(vk)
    strip = unshuffle(jax.lax.all_gather(a_loc, "row", tiled=False),
                      lay.p, lay.nb)[:m]
    strip = strip[idx]
    swapped = strip[jnp.clip(gr, 0, m - 1)]
    a_loc = jnp.where(((gr >= j) & (gr < m))[:, None], swapped, a_loc)
    pcol = jnp.concatenate([colpan[:j], pan]) if j else pan
    a_loc = _write_panel(a_loc, lay, r, c, j, w, pcol, row_lo=j)
    if j + w < n:
        u12 = rtrsm_left_lower(pan[:w], strip[j:j + w], unit_diag=True)
        u12_mine = u12[jnp.clip(gr - j, 0, w - 1)]
        rmask = ((gr >= j) & (gr < j + w))[:, None]
        cmask = ((gc >= j + w) & (gc < n))[None, :]
        a_loc = jnp.where(rmask & cmask, u12_mine, a_loc)
        if j + w < m:
            l21 = pan[jnp.clip(gr - j, 0, m - j - 1)]
            upd = rgemm(l21, u12, a_loc, alpha=-1.0, beta=1.0,
                        backend=gemm_backend)
            tmask = (((gr >= j + w) & (gr < m))[:, None]
                     & ((gc >= j + w) & (gc < n))[None, :])
            a_loc = jnp.where(tmask, upd, a_loc)
    return a_loc, ipiv, okc


@functools.partial(jax.jit, static_argnames=("lay", "mesh", "j",
                                             "gemm_backend", "plan",
                                             "active"))
def _p_rpotrf_ft_step(a, *, lay, mesh, j, gemm_backend, plan, active):
    fn = functools.partial(_rpotrf_ft_step_local, lay=lay, j=j,
                           gemm_backend=gemm_backend, plan=plan,
                           active=active)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC,),
                     out_specs=(_SPEC, _REP), check_vma=False)(a)


@functools.partial(jax.jit, static_argnames=("lay", "mesh", "j",
                                             "gemm_backend", "plan",
                                             "active"))
def _p_rgetrf_ft_step(a, ipiv, *, lay, mesh, j, gemm_backend, plan, active):
    fn = functools.partial(_rgetrf_ft_step_local, lay=lay, j=j,
                           gemm_backend=gemm_backend, plan=plan,
                           active=active)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC, _REP),
                     out_specs=(_SPEC, _REP, _REP), check_vma=False)(a, ipiv)


def _potrf_keep_local(a_loc, *, lay: BlockCyclic):
    r, c = grid_coords()
    gr = local_gidx(lay, 0, r)
    gc = local_gidx(lay, 1, c)
    n = lay.n
    keep = ((gr[:, None] >= gc[None, :]) & (gr < n)[:, None]
            & (gc < n)[None, :])
    return jnp.where(keep, a_loc, 0)


def _getrf_keep_local(a_loc, *, lay: BlockCyclic):
    r, c = grid_coords()
    gr = local_gidx(lay, 0, r)
    gc = local_gidx(lay, 1, c)
    keep = (gr < lay.m)[:, None] & (gc < lay.n)[None, :]
    return jnp.where(keep, a_loc, 0)


@functools.partial(jax.jit, static_argnames=("lay", "mesh", "algo"))
def _p_keep_mask(a, *, lay, mesh, algo):
    fn = functools.partial(_potrf_keep_local if algo == "potrf"
                           else _getrf_keep_local, lay=lay)
    return shard_map(fn, mesh=mesh, in_specs=(_SPEC,), out_specs=_SPEC,
                     check_vma=False)(a)


def _ckpt_save(checkpoint_dir, step, tree, keep_last):
    import numpy as np
    from repro.checkpoint.store import save_checkpoint
    save_checkpoint(checkpoint_dir, step,
                    {k: np.asarray(v) for k, v in tree.items()},
                    keep_last=keep_last)


def _ckpt_restore(checkpoint_dir, like):
    """(step, {name: np array}) of the newest checkpoint restored into
    the structure of ``like``, or (0, None) when none exist."""
    from repro.checkpoint.store import latest_step, restore_checkpoint
    step = latest_step(checkpoint_dir)
    if step is None:
        return 0, None
    tree, step, _ = restore_checkpoint(checkpoint_dir, like, step)
    return step, tree


def p_rpotrf_ft(a: DistMatrix, gemm_backend: str = "xla_quire", plan=None,
                max_retries: int = 2, checkpoint_dir=None,
                resume: bool = False, keep_last: int = 2,
                _stop_after=None):
    """Checksum-protected distributed Cholesky: returns
    (L DistMatrix, FtReport), bit-identical to ``p_rpotrf`` (and hence to
    single-device ``rpotrf``) fault-free and after recovery.

    Host-stepped: every panel broadcast carries its exact checksum strip
    and a failed verify retries just that step (re-broadcast + local
    recompute).  With ``checkpoint_dir`` set, the sharded state is saved
    per block step (repro.checkpoint.store: posit words as int32 npy,
    sha256-verified) and ``resume=True`` restarts from the newest step,
    resuming bit-identically — posit words are exact integer state, so a
    resumed run produces the same factor word-for-word.  ``_stop_after``
    (test hook) simulates a mid-factorization kill: the driver returns
    (None, report) after that many steps.
    """
    from repro import ft
    lay = a.layout
    if lay.m != lay.n:
        raise ValueError(f"Cholesky needs square A, got {a.shape}")
    data = a.data
    report = ft.FtReport()
    start = 0
    if checkpoint_dir is not None and resume:
        start, state = _ckpt_restore(checkpoint_dir, {"a": data})
        if state is not None:
            data = jax.device_put(state["a"], data.sharding)
    steps = list(range(0, lay.n, lay.nb))
    for s, j in enumerate(steps):
        if s < start:
            continue
        prev = data
        for attempt in range(max_retries + 1):
            data, okc = _p_rpotrf_ft_step(prev, lay=lay, mesh=a.mesh, j=j,
                                          gemm_backend=gemm_backend,
                                          plan=plan, active=(attempt == 0))
            if int(okc) == lay.p * lay.q:
                report.retries += attempt
                break
            report.detections += 1
            report.sites.append(("dist.panel", s))
            _obs_metrics.inc("ft.detections")
            _obs_metrics.inc("ft.retries")
        else:
            report.failed = True
            from repro.ft.abft import AbftError
            raise AbftError(f"p_rpotrf_ft: step {s} broadcast mismatch "
                            f"persisted across {max_retries + 1} attempts")
        if checkpoint_dir is not None:
            _ckpt_save(checkpoint_dir, s + 1, {"a": data},
                       keep_last=keep_last)
        if _stop_after is not None and s + 1 >= _stop_after \
                and s + 1 < len(steps):
            return None, report
    data = _p_keep_mask(data, lay=lay, mesh=a.mesh, algo="potrf")
    return a.with_data(data), report


def p_rgetrf_ft(a: DistMatrix, gemm_backend: str = "xla_quire", plan=None,
                max_retries: int = 2, checkpoint_dir=None,
                resume: bool = False, keep_last: int = 2,
                _stop_after=None):
    """Checksum-protected distributed partial-pivot LU: returns
    (LU DistMatrix, ipiv, FtReport) — contract, checkpointing, and the
    ``_stop_after`` kill hook as in ``p_rpotrf_ft`` (which see);
    returns (None, None, report) when the kill hook fires."""
    from repro import ft
    lay = a.layout
    mn = min(lay.m, lay.n)
    data = a.data
    ipiv = jnp.zeros((mn,), jnp.int32)
    report = ft.FtReport()
    start = 0
    if checkpoint_dir is not None and resume:
        start, state = _ckpt_restore(checkpoint_dir,
                                     {"a": data, "ipiv": ipiv})
        if state is not None:
            data = jax.device_put(state["a"], data.sharding)
            ipiv = jnp.asarray(state["ipiv"], jnp.int32)
    steps = list(range(0, mn, lay.nb))
    for s, j in enumerate(steps):
        if s < start:
            continue
        prev, ipiv_prev = data, ipiv
        for attempt in range(max_retries + 1):
            data, ipiv, okc = _p_rgetrf_ft_step(
                prev, ipiv_prev, lay=lay, mesh=a.mesh, j=j,
                gemm_backend=gemm_backend, plan=plan,
                active=(attempt == 0))
            if int(okc) == lay.p * lay.q:
                report.retries += attempt
                break
            report.detections += 1
            report.sites.append(("dist.panel", s))
            _obs_metrics.inc("ft.detections")
            _obs_metrics.inc("ft.retries")
        else:
            report.failed = True
            from repro.ft.abft import AbftError
            raise AbftError(f"p_rgetrf_ft: step {s} broadcast mismatch "
                            f"persisted across {max_retries + 1} attempts")
        if checkpoint_dir is not None:
            _ckpt_save(checkpoint_dir, s + 1, {"a": data, "ipiv": ipiv},
                       keep_last=keep_last)
        if _stop_after is not None and s + 1 >= _stop_after \
                and s + 1 < len(steps):
            return None, None, report
    data = _p_keep_mask(data, lay=lay, mesh=a.mesh, algo="getrf")
    return a.with_data(data), ipiv, report
