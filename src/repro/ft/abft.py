"""Exact ABFT: ones-weighted checksums carried as canonical quire limb
planes, verified by exact integer equality (DESIGN.md §11).

The classic Huang–Abraham trick keeps row/column sums alongside a
matrix and checks them after every operation — in floating point the
check needs a norm tolerance, because the checksum is computed through
differently-rounded paths.  Here it does not: a checksum is the EXACT
ones-weighted sum of the posit words' values, accumulated through the
same quire limb path every other exact op in the repo uses
(``_decode_half`` + ``_deposit`` + integer limb adds), then carried in
canonical (carry-propagated) form.  Integer limb adds are associative,
so "sum of the words" is one well-defined integer state — and posit
words are value-injective (every bit pattern is a distinct value; NaR is
tracked as a flag), so ANY change to any word changes the exact sum.
Detection of a corrupted word is therefore total, and verification of
uncorrupted data can never fail (the recompute is the same deterministic
program on the same words): exact word-equality checking, zero false
positives, no threshold to tune.

Narrow formats (p16e1/p8) store words sign-extended in int32; flips in
the redundant sign-extension bits don't change the VALUE, so each
checksum also carries the raw int64 word sum — a change to any stored
bit changes that sum.  (For p32e2 the value checksums alone are already
total.)

Protected ops follow one shape: produce -> derive checksums (atomic with
the compute) -> [injection window: storage / communication faults
strike here] -> verify before consuming -> on mismatch, localize via the
row x column mismatch intersection and recompute from the last verified
state (bounded retry budget).  Faults *inside* a GEMM's arithmetic are
out of scope (that is TMR territory); the model is the deployment
concern the paper's FPGA/GPU regime actually has — corrupted words in
BRAM/HBM or on the interconnect (ft/inject.py).

Cost: checksumming an (M, N) matrix is O(M N) limb deposits — one
GEMM's K-loop iteration, amortized over the O(M N K) compute it
protects.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import P32E2, PositFormat
from repro.kernels.ops import rgemm
from repro.obs import metrics as _obs_metrics
from repro.quire.gemm import quire_gemm_limbs
from repro.quire.quire import (Quire, _decode_half, _deposit, _F, _I64,
                               q_renorm, q_to_posit, quire_limbs,
                               quire_lsb_exp)
from repro.ft.inject import FaultPlan
from repro.ft.report import FtReport


class AbftError(RuntimeError):
    """Checksum mismatch persisted past the bounded retry budget."""


def _word_limbs(words, fmt: PositFormat):
    """Per-word quire deposit: (...,) posit words -> ((..., L) int64
    redundant limbs, (...) nar flags).  Summing these limbs along an
    axis IS the ones-weighted checksum — the same deposit primitive as
    ``qadd_posit``, vectorized."""
    w = jnp.asarray(words, jnp.int32)
    f, c, sgn, nar = _decode_half(w, fmt)
    idx0 = c - _F - quire_lsb_exp(fmt)
    L = quire_limbs(fmt)
    limbs = _deposit(jnp.zeros(w.shape + (L,), _I64), f, idx0, sgn)
    return limbs, nar


def word_sums(words, fmt: PositFormat, axis: int):
    """Exact ones-weighted sum of posit-word VALUES along ``axis``, as
    canonical quire limbs: ((..., L) limbs, (...) nar).  Headroom: each
    word deposits < 2^32 per limb, so up to 2^31 words per sum."""
    limbs, nar = _word_limbs(words, fmt)
    axis = axis % (limbs.ndim - 1)                 # L axis excluded
    q = q_renorm(Quire(limbs=jnp.sum(limbs, axis=axis),
                       nar=jnp.any(nar, axis=axis)))
    return q.limbs, q.nar


def limb_sums(limbs, nar, axis: int):
    """Canonical checksum of a pre-rounding limb STATE (M, N, L) along
    ``axis`` — the limb-plane analogue of ``word_sums`` for protecting a
    quire accumulator before its single rounding."""
    axis = axis % (limbs.ndim - 1)
    q = q_renorm(Quire(limbs=jnp.sum(limbs, axis=axis),
                       nar=jnp.any(nar, axis=axis)))
    return q.limbs, q.nar


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Checksums:
    """Row/column checksums of an (M, N) posit-word matrix: canonical
    value-sum limb planes, nar flags, and raw int64 word sums."""
    row: jax.Array                                 # (M, L) int64
    col: jax.Array                                 # (N, L) int64
    row_nar: jax.Array                             # (M,) bool
    col_nar: jax.Array                             # (N,) bool
    row_w: jax.Array                               # (M,) int64 raw word sum
    col_w: jax.Array                               # (N,) int64

    def tree_flatten(self):
        return ((self.row, self.col, self.row_nar, self.col_nar,
                 self.row_w, self.col_w), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def checksum(words, fmt: PositFormat = P32E2) -> Checksums:
    """Production-time checksums of an (M, N) posit-word matrix."""
    w = jnp.asarray(words, jnp.int32)
    row, row_nar = word_sums(w, fmt, axis=1)
    col, col_nar = word_sums(w, fmt, axis=0)
    w64 = w.astype(_I64)
    return Checksums(row=row, col=col, row_nar=row_nar, col_nar=col_nar,
                     row_w=jnp.sum(w64, axis=1), col_w=jnp.sum(w64, axis=0))


def verify(words, cks: Checksums, fmt: PositFormat = P32E2):
    """Recompute ``checksum(words)`` and compare by exact integer
    equality.  Returns (ok scalar bool, bad_row (M,) bool, bad_col (N,)
    bool): a single corrupted word flags exactly its row AND its column,
    which is what ``locate`` intersects."""
    got = checksum(words, fmt)
    bad_row = (jnp.any(got.row != cks.row, axis=-1)
               | (got.row_nar != cks.row_nar) | (got.row_w != cks.row_w))
    bad_col = (jnp.any(got.col != cks.col, axis=-1)
               | (got.col_nar != cks.col_nar) | (got.col_w != cks.col_w))
    return ~(jnp.any(bad_row) | jnp.any(bad_col)), bad_row, bad_col


_checksum_jit = jax.jit(checksum, static_argnames=("fmt",))
_verify_jit = jax.jit(verify, static_argnames=("fmt",))


def locate(bad_row, bad_col, nb: int = 1):
    """First corrupted (row, col) — in units of ``nb``-sized blocks —
    from ``verify``'s concrete mismatch masks; -1 where no mismatch."""
    r = np.flatnonzero(np.asarray(bad_row))
    c = np.flatnonzero(np.asarray(bad_col))
    return (int(r[0]) // nb if r.size else -1,
            int(c[0]) // nb if c.size else -1)


# --------------------------------------------------------------------------
# protected GEMMs
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "alpha", "beta", "trans_a", "trans_b", "backend", "fmt"))
def _rgemm_ft_jit(a_p, b_p, c_p, *, alpha, beta, trans_a, trans_b, backend,
                  fmt):
    """Protected-GEMM produce leg: the UNMODIFIED ``rgemm`` program plus
    production checksums, one dispatch.  The injection window and the
    verify leg run outside this program (host-level), so the compiled
    program is independent of the fault plan — one cache entry serves
    every plan."""
    out = rgemm(a_p, b_p, c_p, alpha=alpha, beta=beta, trans_a=trans_a,
                trans_b=trans_b, backend=backend, fmt=fmt)
    return out, checksum(out, fmt)


def rgemm_ft(a_p, b_p, c_p=None, alpha=1.0, beta=0.0, trans_a: bool = False,
             trans_b: bool = False, backend: str = "quire_exact",
             fmt: PositFormat = P32E2, plan: FaultPlan | None = None,
             step: int = 0, max_retries: int = 2):
    """Checksum-protected ``rgemm``: returns (C, Checksums, FtReport).

    Fault-free, the words are bit-identical to the unprotected ``rgemm``
    (the compute IS the unprotected jitted program; the checksum legs
    only read its output).  Injection site ``"rgemm.out"`` sits between
    checksum production and verification; a detected mismatch recomputes
    (bounded by ``max_retries``), and exhaustion raises ``AbftError``.
    The returned ``Checksums`` let a consumer re-verify C after further
    storage/communication (the blocked drivers do exactly this per block
    step)."""
    report = FtReport()
    for attempt in range(max_retries + 1):
        out, cks = _rgemm_ft_jit(
            a_p, b_p, c_p, alpha=alpha, beta=beta, trans_a=trans_a,
            trans_b=trans_b, backend=backend, fmt=fmt)
        if attempt == 0 and plan is not None:
            out = plan.words("rgemm.out", step, out, fmt)
        ok, bad_row, bad_col = _verify_jit(out, cks, fmt=fmt)
        if bool(ok):
            report.retries = attempt
            return out, cks, report
        report.detections += 1
        report.sites.append(("rgemm.out", step, locate(bad_row, bad_col)))
        _obs_metrics.inc("ft.detections")
        _obs_metrics.inc("ft.retries")
    report.failed = True
    raise AbftError(f"rgemm_ft: mismatch persisted across "
                    f"{max_retries + 1} attempts at {report.sites}")


@functools.partial(jax.jit, static_argnames=("fmt",))
def _quire_limbs_cks_jit(a_p, b_p, *, fmt):
    """Quire-GEMM produce leg: the pre-rounding limb state plus its
    limb-plane checksums (plan-independent program)."""
    limbs, nar = quire_gemm_limbs(a_p, b_p, fmt)
    lrow, lrow_nar = limb_sums(limbs, nar, axis=1)
    lcol, lcol_nar = limb_sums(limbs, nar, axis=0)
    return limbs, nar, lrow, lrow_nar, lcol, lcol_nar


@jax.jit
def _limb_verify_jit(limbs, nar, lrow, lrow_nar, lcol, lcol_nar):
    """Recompute the limb-state checksums and compare exactly."""
    grow, grow_nar = limb_sums(limbs, nar, axis=1)
    gcol, gcol_nar = limb_sums(limbs, nar, axis=0)
    return ~(jnp.any(grow != lrow) | jnp.any(gcol != lcol)
             | jnp.any(grow_nar != lrow_nar)
             | jnp.any(gcol_nar != lcol_nar))


@functools.partial(jax.jit, static_argnames=("fmt",))
def _round_cks_jit(limbs, nar, *, fmt):
    """Round the verified limb state once and checksum the words."""
    out = q_to_posit(Quire(limbs=limbs, nar=nar), fmt)
    return out, checksum(out, fmt)


def quire_gemm_ft(a_p, b_p, fmt: PositFormat = P32E2,
                  plan: FaultPlan | None = None, step: int = 0,
                  max_retries: int = 2):
    """Limb-plane-protected quire-exact GEMM: like ``rgemm_ft`` with
    backend='quire_exact', but additionally carries checksums of the
    int64 limb STATE across the pre-rounding window, so flips injected
    into the quire accumulator planes (site ``"rgemm.limbs"``) are
    caught before the single rounding can launder them into a plausible
    posit word.  Returns (C, Checksums, FtReport)."""
    report = FtReport()
    for attempt in range(max_retries + 1):
        limbs, nar, lrow, lrow_nar, lcol, lcol_nar = _quire_limbs_cks_jit(
            a_p, b_p, fmt=fmt)
        if attempt == 0 and plan is not None:
            limbs = plan.limbs("rgemm.limbs", step, limbs)
        ok_limbs = _limb_verify_jit(limbs, nar, lrow, lrow_nar, lcol,
                                    lcol_nar)
        out, cks = _round_cks_jit(limbs, nar, fmt=fmt)
        if attempt == 0 and plan is not None:
            out = plan.words("rgemm.out", step, out, fmt)
        ok_words, bad_row, bad_col = _verify_jit(out, cks, fmt=fmt)
        if bool(ok_limbs) and bool(ok_words):
            report.retries = attempt
            return out, cks, report
        report.detections += 1
        site = "rgemm.limbs" if not bool(ok_limbs) else "rgemm.out"
        report.sites.append((site, step, locate(bad_row, bad_col)))
        _obs_metrics.inc("ft.detections")
        _obs_metrics.inc("ft.retries")
    report.failed = True
    raise AbftError(f"quire_gemm_ft: mismatch persisted across "
                    f"{max_retries + 1} attempts at {report.sites}")
