"""Structured fault-tolerance outcome records (no jax imports — safe to
import from anywhere in the stack without cycles)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class FtReport:
    """What a protected driver saw: every detection is a checksum
    mismatch (exact integer inequality — zero false positives), every
    retry a recompute of the offending step from its verified
    predecessor state."""
    detections: int = 0
    retries: int = 0
    failed: bool = False                  # retry budget exhausted
    sites: list = dataclasses.field(default_factory=list)

    def merge(self, other: "FtReport") -> "FtReport":
        self.detections += other.detections
        self.retries += other.retries
        self.failed = self.failed or other.failed
        self.sites.extend(other.sites)
        return self


@dataclasses.dataclass
class SolveReport:
    """Outcome of a graceful-degradation solve (lapack.refine
    ``rgesv_guarded``): which rung of the mp -> ir -> plain ladder
    produced x, why the monitor stopped, and the fault/retry totals."""
    outcome: str                          # converged|stalled|diverged|nar|plain
    solver: str                           # rgesv_mp | rgesv_ir | rgetrs
    sweeps: int = 0
    r_norm: float = 0.0
    r_norm0: float = 0.0
    detections: int = 0
    retries: int = 0
    fallbacks: tuple = ()                 # ladder rungs abandoned, in order
