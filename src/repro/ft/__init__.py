"""repro.ft — exact-ABFT fault tolerance for the posit linear-algebra
stack (DESIGN.md §11).

Three legs:

* ``ft.abft``   — quire-exact ones-weighted checksums (canonical integer
                  limb planes) + verification by exact word equality;
                  ``rgemm_ft`` / ``quire_gemm_ft`` protected GEMMs.
* ``ft.inject`` — deterministic, seeded fault injector: pure jittable
                  word/limb XOR transforms driven by a static schedule
                  (site, step, lane), usable under jit / vmap /
                  shard_map.
* ``ft.report`` — structured outcome records (``FtReport`` for the
                  protected drivers, ``SolveReport`` for the graceful-
                  degradation solve ladder in ``lapack.refine``).

The protected factorization drivers (``rpotrf_ft`` / ``rgetrf_ft`` /
``rgeqrf_ft``) live next to their unprotected originals in
``lapack.decomp`` / ``lapack.qr``; the distributed variants in
``dist.pdecomp``.  Nothing in this package touches the unprotected entry
points: their lowered HLO stays byte-identical (the zero-cost contract,
pinned in tests/test_ft.py with the tests/test_obs.py mechanism).
"""
from repro.ft.abft import (Checksums, checksum, locate, quire_gemm_ft,
                           rgemm_ft, verify)
from repro.ft.inject import Fault, FaultPlan, make_plan
from repro.ft.report import FtReport, SolveReport

__all__ = [
    "Checksums", "checksum", "verify", "locate", "rgemm_ft",
    "quire_gemm_ft", "Fault", "FaultPlan", "make_plan", "FtReport",
    "SolveReport",
]
