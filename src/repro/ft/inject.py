"""Deterministic seeded fault injection for the ABFT stack.

A fault plan is built ON THE HOST from a seed (numpy Generator — all
randomness happens here, once), then applied as a **pure, jittable
word-XOR transform**: the plan is a static (hashable, frozen) schedule,
so applying it inside jit / vmap / shard_map traces to fixed-index
scatter ops with no RNG state — same seed + schedule means bit-identical
injected words on every backend, every dispatch shape, every grid
(pinned in tests/test_ft.py).

Fault model (DESIGN.md §11): transient corruption of STORED or
COMMUNICATED values — a posit word (or quire limb plane) flips between
the instant a protected op produces it (and its checksums) and the
instant a consumer verifies it.  Injection sites in the protected
drivers sit exactly in that window, which is why detection is total:
any word change changes the exact checksum sum.

Schedule coordinates:

* ``site`` — a dataflow location name (``"rgemm.out"``,
  ``"rgetrf.step"``, ``"dist.panel"``, ``"rgemm.limbs"``, ...); each
  protected driver documents the sites it exposes.
* ``step`` — block-step / sweep index the fault fires on (-1 = every
  step).
* ``lane`` — flat element index into the target array (row-major,
  reduced mod size so any lane is valid for any shape).
* ``bit`` — bit to flip (0..31 for posit words, 0..63 for int64 limbs).
* ``kind`` — ``"flip"`` (XOR one bit), ``"nar"`` (overwrite with the
  format's NaR pattern), ``"saturate"`` (overwrite with maxpos).
* ``dev`` — for distributed sites: linear device id (r * Q + c) whose
  replica is corrupted (-1 = all devices).  A broadcast fault hits one
  receiver, not the wire.

Faults fire only on a driver's FIRST attempt at a step (transient soft
errors don't recur); the retry lane re-runs the same program with
injection disabled, which is what makes recovery bit-identical.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.formats import P32E2, PositFormat

_KINDS = ("flip", "nar", "saturate")


def _i32_mask(bit: int) -> int:
    """XOR mask for posit-word bit ``bit`` as a Python int in int32
    range (bit 31 is the sign/NaR bit: mask -2^31)."""
    m = 1 << (bit & 31)
    return m - (1 << 32) if m >= (1 << 31) else m


@dataclasses.dataclass(frozen=True)
class Fault:
    site: str
    step: int = 0
    lane: int = 0
    bit: int = 0
    kind: str = "flip"
    dev: int = -1

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A static, hashable injection schedule (usable as a jit static
    argument).  ``words`` / ``limbs`` are the two apply transforms."""
    faults: tuple = ()

    def at(self, site: str, step: int):
        return tuple(f for f in self.faults
                     if f.site == site and f.step in (-1, step))

    def words(self, site: str, step: int, words, fmt: PositFormat = P32E2,
              dev=None):
        """Apply every matching fault to an int32 posit-word array.
        ``dev`` (traced scalar, linear device id) gates device-targeted
        faults inside shard_map programs; None applies them all."""
        hits = self.at(site, step)
        if not hits:
            return words
        out = jnp.asarray(words, jnp.int32)
        shape, size = out.shape, out.size
        flat = out.ravel()
        for f in hits:
            i = f.lane % size
            if f.kind == "flip":
                bad = flat[i] ^ jnp.int32(_i32_mask(f.bit))
            elif f.kind == "nar":
                bad = jnp.int32(fmt.nar_pattern)
            else:                                        # saturate: +maxpos
                bad = jnp.int32((1 << (fmt.nbits - 1)) - 1)
            if f.dev >= 0 and dev is not None:
                bad = jnp.where(jnp.asarray(dev) == f.dev, bad, flat[i])
            flat = flat.at[i].set(bad)
        return flat.reshape(shape)

    def limbs(self, site: str, step: int, limbs, dev=None):
        """Apply matching bit flips to an int64 quire limb-plane array
        (``nar``/``saturate`` kinds are word-domain; they are ignored
        here)."""
        hits = [f for f in self.at(site, step) if f.kind == "flip"]
        if not hits:
            return limbs
        out = jnp.asarray(limbs, jnp.int64)
        shape, size = out.shape, out.size
        flat = out.ravel()
        for f in hits:
            i = f.lane % size
            m = 1 << (f.bit & 63)
            mask = jnp.int64(m - (1 << 64) if m >= (1 << 63) else m)
            bad = flat[i] ^ mask
            if f.dev >= 0 and dev is not None:
                bad = jnp.where(jnp.asarray(dev) == f.dev, bad, flat[i])
            flat = flat.at[i].set(bad)
        return flat.reshape(shape)


def make_plan(seed: int, site: str, size: int, steps: int = 1, n: int = 1,
              kinds=("flip",), nbits: int = 32, devs: int = 0) -> FaultPlan:
    """Seeded random schedule: ``n`` faults at ``site``, each with a
    uniform step in [0, steps), lane in [0, size), bit in [0, nbits),
    kind from ``kinds``, and (if ``devs`` > 0) a target device in
    [0, devs).  Deterministic in ``seed`` — the soak tests sweep seeds
    and assert 100% detection."""
    rng = np.random.default_rng(seed)
    faults = []
    for _ in range(n):
        faults.append(Fault(
            site=site, step=int(rng.integers(steps)),
            lane=int(rng.integers(size)), bit=int(rng.integers(nbits)),
            kind=str(kinds[int(rng.integers(len(kinds)))]),
            dev=int(rng.integers(devs)) if devs else -1))
    return FaultPlan(tuple(faults))
