"""positscope metrics: a process-local registry of counters, gauges and
fixed-log2-bucket histograms behind a context-manager collector.

Design contract (DESIGN.md §10): observability is OFF unless a
``scoped()`` collector is active, and every recording entry point is a
Python-level no-op in that state — ``if not _STACK: return`` before any
other work.  Nothing here is ever traced into a jitted program: the
instrumented library code gates on ``numerics.active(...)``, which is
False both when no collector is open and when the inputs are tracers
(i.e. the instrumented call is itself being traced into an outer jit),
so the lowered programs of the hot paths are byte-identical with the
package absent (pinned in tests/test_obs.py).

Instruments:

* ``inc(name, v)``        — monotonic counters (events, bytes, sweeps)
* ``gauge(name, v)``      — last-value gauges (occupancy fractions, norms)
* ``observe(name, v)``    — histogram of floor(log2(|v|)) with a
                            dedicated zero bucket; fixed bucketing means
                            histograms merge exactly across scopes
* ``observe_hist(name, {bucket: count})`` — merge a precomputed integer
                            histogram (the jitted numerics collectors
                            hand their bincounts over in one call)
* ``record(name, **row)`` — append a row to a named time series (per
                            block-step / per IR-sweep telemetry)

Collectors nest: every instrument records into ALL open scopes, so an
outer benchmark scope sees the totals of inner instrumented regions.
``Collector.to_json()`` serializes everything; ``save_chrome_trace()``
writes the span events (obs/trace.py) as Chrome ``trace_event`` JSON
loadable in Perfetto / chrome://tracing.
"""
from __future__ import annotations

import contextlib
import json
import math
import time

# The open-collector stack.  Module-level and deliberately not
# thread-local: the stack is the single enabled/disabled switch and the
# repo's drivers are single-threaded host loops.
_STACK: list["Collector"] = []

# Histogram bucket index reserved for exact zeros (log2 undefined).
ZERO_BUCKET = -(1 << 30)


def enabled() -> bool:
    """True iff at least one ``scoped()`` collector is open."""
    return bool(_STACK)


def log2_bucket(value) -> int:
    """floor(log2(|value|)), with 0 / NaN mapped to the zero bucket."""
    v = abs(float(value))
    if v == 0.0 or math.isnan(v) or math.isinf(v):
        return ZERO_BUCKET
    return int(math.floor(math.log2(v)))


class Collector:
    """One observation scope: plain-Python dicts, merged-on-record."""

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, dict[int, int]] = {}
        self.series: dict[str, list[dict]] = {}
        self.events: list[dict] = []          # chrome trace_event dicts
        self.t0 = time.perf_counter()         # trace timebase (µs origin)

    # -- export ------------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "hists": {k: {str(b): c for b, c in sorted(v.items())}
                      for k, v in self.hists.items()},
            "series": {k: list(v) for k, v in self.series.items()},
            "spans": len(self.events),
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def bench_block(self) -> dict:
        """Compact block for BENCH_*.json rows: counters + gauges only
        (histograms/series are too bulky for per-row trajectory data)."""
        return {"counters": {k: round(v, 6) for k, v in
                             sorted(self.counters.items())},
                "gauges": {k: round(v, 6) for k, v in
                           sorted(self.gauges.items())}}

    def chrome_trace(self) -> dict:
        """Chrome trace_event JSON object (Perfetto's legacy JSON format):
        complete ("ph": "X") events with µs timestamps/durations."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")


@contextlib.contextmanager
def scoped(collector: "Collector | None" = None):
    """Open a collector scope::

        with obs.scoped() as m:
            rgesv_ir(a_p, b_p)
        print(m.to_json())

    Everything instrumented underneath records into ``m`` (and into any
    enclosing scopes).  On exit the stack entry is removed; the collector
    object stays alive for export.  Pass an existing ``Collector`` to
    keep accumulating into it across several scopes (one trace timeline
    over many solves — its ``t0`` timebase is preserved)."""
    c = Collector() if collector is None else collector
    _STACK.append(c)
    try:
        yield c
    finally:
        _STACK.remove(c)


# --------------------------------------------------------------------------
# recording entry points — every one is a no-op when no scope is open
# --------------------------------------------------------------------------

def inc(name: str, value=1) -> None:
    if not _STACK:
        return
    v = float(value)
    for c in _STACK:
        c.counters[name] = c.counters.get(name, 0.0) + v


def gauge(name: str, value) -> None:
    if not _STACK:
        return
    v = float(value)
    for c in _STACK:
        c.gauges[name] = v


def observe(name: str, value) -> None:
    if not _STACK:
        return
    b = log2_bucket(value)
    for c in _STACK:
        h = c.hists.setdefault(name, {})
        h[b] = h.get(b, 0) + 1


def observe_hist(name: str, buckets: dict) -> None:
    """Merge ``{bucket_index: count}`` into histogram ``name`` (fixed
    bucketing makes the merge a plain integer add)."""
    if not _STACK:
        return
    items = [(int(b), int(v)) for b, v in buckets.items() if int(v)]
    for c in _STACK:
        h = c.hists.setdefault(name, {})
        for b, v in items:
            h[b] = h.get(b, 0) + v


def record(name: str, **row) -> None:
    """Append one row to series ``name``; values are coerced to plain
    Python scalars so the series is JSON-clean (this is the point where
    jitted telemetry outputs leave device memory — only ever on the
    enabled path)."""
    if not _STACK:
        return
    clean = {}
    for k, v in row.items():
        if isinstance(v, (str, bool, int)):
            clean[k] = v
        else:
            try:
                clean[k] = float(v)
            except (TypeError, ValueError):
                clean[k] = str(v)
    for c in _STACK:
        c.series.setdefault(name, []).append(clean)
