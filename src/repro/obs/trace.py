"""positscope spans: nested wall-clock spans serialized as Chrome
``trace_event`` JSON (Perfetto / chrome://tracing's legacy format).

``span(name, **attrs)`` is a context manager that

* is a **no-op** when no ``obs.scoped()`` collector is open (the null
  path touches one module-level list and yields — nothing is timed,
  nothing allocated);
* times the region with ``time.perf_counter``;
* forwards the region to ``jax.profiler.TraceAnnotation`` so spans show
  up inside a JAX/XLA profiler trace when one is being captured;
* on exit appends ONE complete event (``"ph": "X"``, microsecond
  ``ts``/``dur`` relative to each collector's creation) to every open
  collector.  Complete events on the same pid/tid nest by ts/dur
  containment, which is exactly how Perfetto renders a blocked
  factorization's panel/update structure.

Spans may carry static attributes (``span("rgetrf", n=256, nb=64)``);
attrs land in the event's ``args`` and must be JSON-representable
scalars/strings.  The current nesting depth and dotted path are recorded
too, so the JSON is greppable without a viewer.
"""
from __future__ import annotations

import contextlib
import os
import time

import jax

from repro.obs import metrics as _metrics

# Host-side span stack (names only) — gives events their dotted path.
_SPAN_STACK: list[str] = []


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


@contextlib.contextmanager
def span(name: str, **attrs):
    """Time a region under ``name`` into every open collector."""
    if not _metrics._STACK:
        yield
        return
    _SPAN_STACK.append(name)
    path = ".".join(_SPAN_STACK)
    depth = len(_SPAN_STACK)
    t0 = time.perf_counter()
    try:
        with jax.profiler.TraceAnnotation(name):
            yield
    finally:
        t1 = time.perf_counter()
        _SPAN_STACK.pop()
        args = {k: _jsonable(v) for k, v in attrs.items()}
        args["path"] = path
        args["depth"] = depth
        for c in _metrics._STACK:
            c.events.append({
                "name": name, "cat": "positscope", "ph": "X",
                "ts": (t0 - c.t0) * 1e6, "dur": (t1 - t0) * 1e6,
                "pid": os.getpid(), "tid": 0, "args": dict(args),
            })
