"""positscope numerics: posit-value telemetry computed from posit words
with pure jittable integer ops (no host decode of individual elements).

The paper's accuracy claim is a statement about WHERE values sit on the
posit regime/fraction trade-off: Posit(nbits, es) keeps its maximal
fraction width (``fmt.max_frac_bits``) only while the regime field is
shortest, i.e. for regime exponent k in {0, -1} — equivalently
|x| in [2^-(2^es), 2^(2^es)), the **golden zone** ([1/16, 16) for
p32e2, [1/4, 4) for p16e1/p8e0, [1/16, 16) for p8e2).  These collectors
measure that occupancy, plus the regime-width and scale (power-of-two
exponent) histograms, rounding/sticky events on the encode path, and
quire limb-carry counts — the evidence layer behind
``error_eval.golden_zone_study``.

Two call shapes:

* ``collect_numerics(words, fmt)`` / ``encode_round_stats(x, fmt)`` /
  ``quire_carry_stats(limbs)`` — jitted, return device scalars/arrays;
  usable standalone or from inside larger jitted telemetry bodies.
* ``record_*`` helpers — host-side, gate on ``active(...)`` and push
  results into the open ``obs.scoped()`` collectors.

``active(*arrays)`` is the zero-cost gate used by every instrumented
library entry point: it is False when no collector is open OR when any
input is a tracer (the caller is itself being traced into an outer jit),
so the disabled path never adds an op to any lowered program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posit
from repro.core.formats import P32E2, PositFormat
from repro.obs import metrics as _metrics

_I64 = jnp.int64


def is_concrete(*arrays) -> bool:
    """True iff none of ``arrays`` is a JAX tracer."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def active(*arrays) -> bool:
    """The instrumentation gate: a collector is open AND the inputs are
    concrete (so running an obs-variant program cannot perturb an outer
    trace).  Resolved entirely at the Python level."""
    return bool(_metrics._STACK) and is_concrete(*arrays)


def golden_zone_bounds(fmt: PositFormat = P32E2) -> tuple[float, float]:
    """[lo, hi) magnitude band where ``fmt`` keeps its maximal fraction
    width (regime exponent k in {0, -1}): [2^-(2^es), 2^(2^es))."""
    return float(2.0 ** -(1 << fmt.es)), float(2.0 ** (1 << fmt.es))


def step_stats(words, fmt: PositFormat = P32E2) -> dict:
    """Small per-stage summary (traceable; all outputs are scalars):
    golden-zone occupancy, mean regime width, zero/NaR counts.  This is
    the payload the obs-variant factorization bodies emit per block step
    — cheap enough to compute for every panel/trailing update."""
    p = jnp.asarray(words, jnp.int32).ravel()
    is_zero, is_nar, _, scale, _ = posit.decode(p, fmt)
    es = fmt.es
    finite = ~(is_zero | is_nar)
    k = scale >> es
    reg_len = jnp.clip(jnp.where(k >= 0, k + 2, 1 - k), 2, fmt.nbits - 1)
    golden = finite & (k >= -1) & (k <= 0)
    nfin = jnp.maximum(jnp.sum(finite.astype(jnp.int64)), 1)
    return {
        "n": jnp.int64(p.size),
        "zero": jnp.sum(is_zero.astype(jnp.int64)),
        "nar": jnp.sum(is_nar.astype(jnp.int64)),
        "golden_frac": jnp.sum(golden.astype(jnp.float64)) / nfin,
        "regime_mean": (jnp.sum(jnp.where(finite, reg_len, 0)
                                .astype(jnp.float64)) / nfin),
    }


@functools.partial(jax.jit, static_argnames=("fmt",))
def collect_numerics(words, fmt: PositFormat = P32E2) -> dict:
    """Full posit-word telemetry of an array of ``fmt`` words:

    * ``regime_hist[w]`` — count of finite words whose regime field
      (run + terminator, as stored) is ``w`` bits wide, w in [2, nbits-1]
    * ``scale_hist[scale + max_scale]`` — count per power-of-two scale
      (the posit "exponent" histogram, fixed log2 bucketing by
      construction)
    * ``golden_frac`` / ``zero`` / ``nar`` / ``regime_mean`` — as in
      ``step_stats``

    Pure int ops on the decoded fields; jitted with ``fmt`` static.
    """
    p = jnp.asarray(words, jnp.int32).ravel()
    is_zero, is_nar, _, scale, _ = posit.decode(p, fmt)
    es = fmt.es
    finite = ~(is_zero | is_nar)
    k = scale >> es
    reg_len = jnp.clip(jnp.where(k >= 0, k + 2, 1 - k), 2, fmt.nbits - 1)
    one = finite.astype(jnp.int32)
    regime_hist = jnp.zeros((fmt.nbits,), jnp.int32).at[
        jnp.where(finite, reg_len, 0)].add(one, mode="drop")
    off = jnp.clip(scale + fmt.max_scale, 0, 2 * fmt.max_scale)
    scale_hist = jnp.zeros((2 * fmt.max_scale + 1,), jnp.int32).at[
        jnp.where(finite, off, 0)].add(one, mode="drop")
    out = step_stats(words, fmt)
    out["regime_hist"] = regime_hist
    out["scale_hist"] = scale_hist
    return out


@functools.partial(jax.jit, static_argnames=("fmt",))
def encode_round_stats(x, fmt: PositFormat = P32E2) -> dict:
    """Rounding-event / sticky-bit counters for encoding f64 carrier
    values into ``fmt`` — the same field dataflow as
    ``posit.chain_round`` (the repo's one encode path), recomputed here
    so the production encode stays untouched:

    * ``total``     — finite nonzero inputs
    * ``rounded``   — in-range inputs whose encode drops nonzero bits
                      (the encoded value differs from the input)
    * ``sticky``    — inputs with sticky bits below the kept+guard field
    * ``saturated`` — inputs clamped to ±maxpos / ±minpos
    """
    x = jnp.asarray(x, jnp.float64).ravel()
    nbits, es = fmt.nbits, fmt.es
    is_nan = jnp.isnan(x) | jnp.isinf(x)
    is_zero = (x == 0.0) & ~is_nan
    tiny = ~is_nan & ~is_zero & (jnp.abs(x) < np.float64(2.0 ** -1022))
    ax = jnp.abs(jnp.where(is_nan | is_zero | tiny, 1.0, x))
    mant, ex = jnp.frexp(ax)
    scale = ex.astype(_I64) - 1
    R = mant * np.float64(1 << 29)
    q = jnp.floor(R)
    sticky = R != q
    frac = q.astype(_I64) & ((_I64(1) << 28) - 1)

    k = scale >> es
    e = scale - (k << es)
    reg_len = jnp.where(k >= 0, k + 2, 1 - k)
    ef = (_I64(1) << (es + 28)) | (e << 28) | frac
    d = jnp.clip(29 + es + reg_len - nbits, 1, es + 28)
    dropped = ef & ((_I64(1) << d) - 1)

    over = scale >= fmt.max_scale
    under = (scale < -fmt.max_scale) | tiny
    finite = ~(is_nan | is_zero)
    in_range = finite & ~over & ~under
    rounded = in_range & ((dropped != 0) | sticky)
    return {
        "total": jnp.sum(finite.astype(jnp.int64)),
        "rounded": jnp.sum(rounded.astype(jnp.int64)),
        "sticky": jnp.sum((in_range & sticky).astype(jnp.int64)),
        "saturated": jnp.sum((finite & (over | under)).astype(jnp.int64)),
    }


@jax.jit
def quire_carry_stats(limbs) -> dict:
    """Lazy-carry telemetry of redundant radix-2^32 quire limb state
    ((..., L) int64, repro.quire layout): run the canonical propagation
    sweep and count limb positions that release a nonzero carry — the
    cross-limb traffic an in-kernel quire implementation would pay.
    Returns per-position counts (``per_limb``, shape (L,)) + the total.
    """
    limbs = jnp.asarray(limbs, jnp.int64)
    L = limbs.shape[-1]
    carry = jnp.zeros(limbs.shape[:-1], jnp.int64)
    counts = []
    for j in range(L):
        v = limbs[..., j] + carry
        carry = v >> 32
        counts.append(jnp.sum((carry != 0).astype(jnp.int64)))
    per_limb = jnp.stack(counts)
    return {"per_limb": per_limb, "total": jnp.sum(per_limb)}


# --------------------------------------------------------------------------
# host-side recorders (no-ops unless a collector is open)
# --------------------------------------------------------------------------

def _hist_to_dict(arr, offset: int = 0) -> dict[int, int]:
    a = np.asarray(arr)
    return {int(i) + offset: int(v) for i, v in enumerate(a) if int(v)}


def record_numerics(name: str, words, fmt: PositFormat = P32E2):
    """Collect + record full word telemetry under ``name.*``; returns the
    stats dict (or None on the disabled path)."""
    if not active(words):
        return None
    st = collect_numerics(words, fmt)
    _metrics.gauge(f"{name}.golden_zone", st["golden_frac"])
    _metrics.gauge(f"{name}.regime_mean", st["regime_mean"])
    _metrics.inc(f"{name}.words", st["n"])
    _metrics.inc(f"{name}.nar", st["nar"])
    _metrics.observe_hist(f"{name}.regime_width",
                          _hist_to_dict(st["regime_hist"]))
    _metrics.observe_hist(f"{name}.scale",
                          _hist_to_dict(st["scale_hist"], -fmt.max_scale))
    return st


def record_encode_stats(name: str, x, fmt: PositFormat = P32E2):
    """Record encode-path rounding counters for f64 carrier values."""
    if not active(x):
        return None
    st = encode_round_stats(x, fmt)
    _metrics.inc(f"{name}.encodes", st["total"])
    _metrics.inc(f"{name}.rounded", st["rounded"])
    _metrics.inc(f"{name}.sticky", st["sticky"])
    _metrics.inc(f"{name}.saturated", st["saturated"])
    return st


def record_quire_carries(name: str, limbs):
    """Record quire limb-carry counts for a redundant limb state."""
    if not active(limbs):
        return None
    st = quire_carry_stats(limbs)
    _metrics.inc(f"{name}.limb_carries", st["total"])
    return st


def emit_factor_steps(name: str, tel) -> None:
    """Flush a blocked-factorization collect-variant telemetry list
    (one dict of ``step_stats`` payloads per block step, keyed by stage:
    "panel" / "update") into the open collectors as a ``name.step``
    series plus summary gauge/counter — shared by the decomp and qr
    obs-variant drivers."""
    if not _metrics._STACK:
        return
    for i, step in enumerate(tel):
        row = {"step": i}
        for stage, st in step.items():
            row[f"{stage}_golden"] = st["golden_frac"]
            row[f"{stage}_regime_mean"] = st["regime_mean"]
            row[f"{stage}_nar"] = st["nar"]
        _metrics.record(f"{name}.step", **row)
    if tel:
        _metrics.gauge(f"{name}.last_panel.golden_zone",
                       tel[-1]["panel"]["golden_frac"])
    _metrics.inc(f"{name}.calls")


def golden_zone_fraction(words, fmt: PositFormat = P32E2) -> float:
    """Host convenience: golden-zone occupancy of an array of words
    (fraction of finite nonzero words with regime exponent k in
    {0, -1}).  Independent of the collector state."""
    return float(step_stats(jnp.asarray(words, jnp.int32), fmt)
                 ["golden_frac"])
