"""positscope — numerics + performance observability (DESIGN.md §10).

Opt-in, zero-cost-when-disabled telemetry for the posit stack::

    from repro import obs

    with obs.scoped() as m:
        (x_hi, x_lo), _ = refine.rgesv_ir(a_p, b_p)
    print(m.to_json())                      # counters/gauges/hists/series
    m.save_chrome_trace("solve_trace.json") # open in Perfetto

Three layers:

* ``obs.metrics`` — process-local registry (counters, gauges, fixed-log2
  histograms, series) behind the ``scoped()`` collector stack;
* ``obs.trace``   — nested wall-clock spans -> Chrome trace_event JSON,
  forwarded to ``jax.profiler.TraceAnnotation``;
* ``obs.numerics``— jittable posit-word telemetry (golden-zone occupancy,
  regime/scale histograms, encode rounding/sticky counters, quire
  limb-carry counts) + the ``active()`` gate the instrumented library
  code uses.

With no collector open every instrument is a Python-level no-op and the
instrumented hot paths dispatch the exact same jitted programs as before
the package existed (pinned in tests/test_obs.py).
"""
from repro.obs.metrics import (Collector, enabled, gauge, inc, observe,
                               observe_hist, record, scoped)
from repro.obs.numerics import (active, collect_numerics, encode_round_stats,
                                golden_zone_bounds, golden_zone_fraction,
                                is_concrete, quire_carry_stats,
                                record_encode_stats, record_numerics,
                                record_quire_carries, step_stats)
from repro.obs.trace import span

__all__ = [
    "Collector", "enabled", "gauge", "inc", "observe", "observe_hist",
    "record", "scoped", "span", "active", "collect_numerics",
    "encode_round_stats", "golden_zone_bounds", "golden_zone_fraction",
    "is_concrete", "quire_carry_stats", "record_encode_stats",
    "record_numerics", "record_quire_carries", "step_stats",
]
