"""Step-atomic checkpointing with manifest + integrity hashes.

Layout:   <dir>/step_<N>/leaf_<i>.npy  +  manifest.json
Writes go to a temp dir and are atomically renamed, so a crash mid-save
never corrupts the latest checkpoint (fault-tolerance requirement).  On a
real cluster each host writes only its param shards (addressable-shard
save); here the single-host path saves full arrays.  ``keep_last`` old
steps are garbage-collected after a successful save.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, keep_last: int = 3,
                    extra: dict | None = None) -> str:
    leaves, treedef = _leaf_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(leaves), "leaves": [],
                "extra": extra or {}}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        path = os.path.join(tmp, f"leaf_{i:05d}.npy")
        np.save(path, arr)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        manifest["leaves"].append({
            "i": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256_16": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep_last)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of ``tree_like`` (validates shape/dtype).
    Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, model expects "
        f"{len(leaves)}")
    out = []
    for i, ref in enumerate(leaves):
        path = os.path.join(d, f"leaf_{i:05d}.npy")
        arr = np.load(path)
        meta = manifest["leaves"][i]
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        if digest != meta["sha256_16"]:
            raise IOError(f"integrity check failed for {path}")
        if list(arr.shape) != list(np.shape(ref)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != model "
                f"{np.shape(ref)}")
        # dtype must round-trip exactly: posit words are int32 and quire
        # limb planes int64 — a silent cast (e.g. int64 limbs loaded
        # where int32 words are expected) would corrupt bit-exact state
        if str(arr.dtype) != meta["dtype"]:
            raise ValueError(
                f"leaf {i}: file dtype {arr.dtype} != manifest "
                f"{meta['dtype']}")
        ref_dtype = getattr(ref, "dtype", None)
        if ref_dtype is None:
            ref_dtype = np.asarray(ref).dtype
        if arr.dtype != np.dtype(ref_dtype):
            raise ValueError(
                f"leaf {i}: checkpoint dtype {arr.dtype} != model "
                f"{np.dtype(ref_dtype)}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out), step, manifest["extra"]


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted([d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                    and not d.endswith(".tmp")])
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
