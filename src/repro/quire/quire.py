"""Quire — the posit standard's exact fixed-point fused accumulator.

The paper's accuracy results (and Ciocirlan et al.'s analysis) hinge on
posit's *fused* operations: a dot product accumulated exactly in a wide
fixed-point register and rounded to posit ONCE.  The standard quire for
Posit(n, es) spans [minpos^2, maxpos^2] with n - 2 carry-guard bits:
4 * max_scale + n bits total (512 bits for p32e2, 128 for p16e1).

This is a pure-JAX, branch-free, vectorized implementation:

* **Limb layout** — radix-2^32: the quire value is

      value = sum_j limbs[..., j] * 2^(32*j + QLSB),   QLSB = -2*max_scale

  with ``L = (4*max_scale + nbits) / 32`` limbs (16 for p32e2, 4 for
  p16e1) stored in **int64** in *redundant* (lazy-carry) form: each limb
  holds a signed partial sum and carries are only propagated at rounding
  time.  Every ``qma`` deposits < 2^32 per limb, so int64 headroom admits
  2^31 fused accumulations between carry propagations — no per-step
  normalization, which is what makes the accumulate loop a fixed-shape
  vector add (MXU/VPU-friendly).  The Pallas-facing layout splits each
  int64 limb into (hi, lo) int32 planes — see ``to_limbs32`` and
  DESIGN.md §6.
* **Exactness** — a posit product has LSB weight (ca - fsa) + (cb - fsb)
  >= -2*max_scale = QLSB (equality at minpos^2), so depositing the 56-bit
  significand product at its scale never drops a set bit: the quire state
  is the mathematically exact sum.  ``q_to_posit`` performs the single
  round-to-nearest-even via the same ``posit.encode`` used by scalar ops.
* **Specials** — NaR is tracked as a per-element flag (any NaR input
  poisons the accumulator, matching quire semantics); exact cancellation
  yields true zero.

Ops: ``quire_zero``, ``quire_from_posit``, ``qma``, ``qadd_posit``,
``qneg``, ``q_renorm``, ``q_to_posit``, and the reductions ``fdp`` /
``quire_dot`` (exact fused dot products, vmap/batch friendly).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P32E2, PositFormat

_I64 = jnp.int64
_M32 = (1 << 32) - 1
# Decoded significands live in [2^F, 2^(F+1)) (posit core working width).
_F = 27


def _i64(x):
    return jnp.asarray(x, dtype=_I64)


def quire_limbs(fmt: PositFormat) -> int:
    """Number of 32-bit limbs: (4*max_scale + nbits) / 32, padded up."""
    bits = 4 * fmt.max_scale + fmt.nbits
    return -(-bits // 32)


def quire_lsb_exp(fmt: PositFormat) -> int:
    """Power-of-two weight of quire bit 0 (= minpos^2's exponent)."""
    return -2 * fmt.max_scale


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quire:
    """Batched quire state: ``limbs`` (..., L) int64 redundant radix-2^32
    limbs, ``nar`` (...) bool poison flag."""
    limbs: jax.Array
    nar: jax.Array

    @property
    def shape(self):
        return self.limbs.shape[:-1]

    def tree_flatten(self):
        return (self.limbs, self.nar), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def quire_zero(shape=(), fmt: PositFormat = P32E2) -> Quire:
    L = quire_limbs(fmt)
    return Quire(limbs=jnp.zeros(tuple(shape) + (L,), _I64),
                 nar=jnp.zeros(shape, bool))


# --------------------------------------------------------------------------
# depositing a signed significand at a scale (the one shared primitive)
# --------------------------------------------------------------------------

def _decode_half(p, fmt: PositFormat):
    """One operand's deposit ingredients: (sig, scale, sgn, nar) with
    sgn in {-1, 0, +1} (0 for zero/NaR dead lanes).  Every accumulate
    path (qma, quire_dot, quire_gemm) combines two of these — keeping
    the dead-lane/sign rule in exactly one place."""
    z, n, s, c, f = posit.decode(p, fmt)
    sgn = jnp.where(z | n, 0, jnp.where(s, -1, 1)).astype(_I64)
    return f, c, sgn, n


def _prod_idx0(ca, cb, fmt: PositFormat):
    """Quire bit index of a significand product's LSB: the product value
    is (fa*fb) * 2^(ca+cb-2F), and quire bit 0 weighs 2^QLSB."""
    return ca + cb - 2 * _F - quire_lsb_exp(fmt)

def _chunks3(mag, idx0):
    """Split ``mag`` (int64, < 2^57) shifted left by ``idx0`` quire-bit
    positions into three 32-bit chunks and their base limb index.

    idx0 may be negative (product LSB below quire bit 0) — legal posit
    products have zero bits there, so the dropped chunks are zero.
    Returns (c0, c1, c2, base) with chunk j at limb base + j.
    """
    t = idx0 + 64                       # >= 0 for every legal posit product
    off = t & 31
    base = (t >> 5) - 2
    p0 = mag & _M32
    p1 = mag >> 32                      # < 2^25
    c0 = (p0 << off) & _M32
    c1 = ((p0 >> (32 - off)) | (p1 << off)) & _M32
    c2 = (p1 >> (32 - off)) & _M32
    return c0, c1, c2, base


def _deposit(limbs, mag, idx0, sgn):
    """limbs (..., L) += sgn * (mag << idx0), branch-free over L."""
    L = limbs.shape[-1]
    c0, c1, c2, base = _chunks3(mag, idx0)
    j = jnp.arange(L, dtype=_I64)                       # (L,)
    b = base[..., None]
    add = (jnp.where(j == b, c0[..., None], 0)
           + jnp.where(j == b + 1, c1[..., None], 0)
           + jnp.where(j == b + 2, c2[..., None], 0))
    return limbs + sgn[..., None] * add


# --------------------------------------------------------------------------
# accumulate ops
# --------------------------------------------------------------------------

def qma(q: Quire, a, b, fmt: PositFormat = P32E2, negate=False) -> Quire:
    """Fused multiply-accumulate: q += (-1)^negate * a * b, exactly.

    a, b: posit words broadcastable to q.shape.  ``negate`` may be a bool
    or a boolean array (per-element negation).
    """
    fa, ca, sga, na = _decode_half(a, fmt)
    fb, cb, sgb, nb = _decode_half(b, fmt)
    prod = fa * fb                                      # < 2^56, exact
    idx0 = _prod_idx0(ca, cb, fmt)
    sgn = sga * sgb
    sgn = jnp.where(jnp.asarray(negate, bool), -sgn, sgn)
    sgn = jnp.broadcast_to(sgn, jnp.broadcast_shapes(sgn.shape, q.shape))
    limbs = _deposit(q.limbs, prod, idx0, sgn)
    return Quire(limbs=limbs, nar=q.nar | na | nb)


def qadd_posit(q: Quire, p, fmt: PositFormat = P32E2, negate=False) -> Quire:
    """q += (-1)^negate * p, exactly (every posit is quire-representable)."""
    f, c, sgn, n = _decode_half(p, fmt)
    idx0 = c - _F - quire_lsb_exp(fmt)
    sgn = jnp.where(jnp.asarray(negate, bool), -sgn, sgn)
    sgn = jnp.broadcast_to(sgn, jnp.broadcast_shapes(sgn.shape, q.shape))
    limbs = _deposit(q.limbs, f, idx0, sgn)
    return Quire(limbs=limbs, nar=q.nar | n)


def quire_from_posit(p, fmt: PositFormat = P32E2) -> Quire:
    p = jnp.asarray(p, jnp.int32)
    return qadd_posit(quire_zero(p.shape, fmt), p, fmt)


def qneg(q: Quire) -> Quire:
    """Exact negation (redundant limbs are signed, so this is elementwise)."""
    return Quire(limbs=-q.limbs, nar=q.nar)


# --------------------------------------------------------------------------
# carry propagation and rounding
# --------------------------------------------------------------------------

def _propagate(limbs):
    """Redundant signed limbs -> canonical (low, final_carry): low[j] in
    [0, 2^32), value = sum low[j]*2^(32j) + carry*2^(32L).  Fixed L steps."""
    L = limbs.shape[-1]
    carry = jnp.zeros(limbs.shape[:-1], _I64)
    lows = []
    for j in range(L):
        v = limbs[..., j] + carry
        lows.append(v & _M32)
        carry = v >> 32                                 # arithmetic: signed
    return jnp.stack(lows, axis=-1), carry


def q_renorm(q: Quire) -> Quire:
    """Propagate carries back into canonical two's-complement limbs,
    restoring full 2^31-accumulation headroom (for streaming use)."""
    low, carry = _propagate(q.limbs)
    # fold the sign carry into the top limb (value unchanged mod 2^(32L);
    # in-range quires keep carry in {0, -1})
    top = low[..., -1] + (carry << 32)
    return Quire(limbs=low.at[..., -1].set(top), nar=q.nar)


def q_to_posit(q: Quire, fmt: PositFormat = P32E2):
    """Round the exact quire value to the nearest posit (RNE), the single
    rounding of a fused op chain.  Branch-free: fixed loops over L."""
    low, carry = _propagate(q.limbs)
    L = low.shape[-1]
    neg = carry < 0

    # magnitude limbs: two's-complement negate when negative (fixed loop)
    ninv = (~low) & _M32
    c2 = jnp.ones(low.shape[:-1], _I64)
    mlist = []
    for j in range(L):
        v = ninv[..., j] + c2
        mlist.append(v & _M32)
        c2 = v >> 32
    mag = jnp.where(neg[..., None], jnp.stack(mlist, axis=-1), low)

    nz = mag != 0
    is_zero = ~jnp.any(nz, axis=-1)
    # global MSB position (bits, over the concatenated limbs)
    j32 = 32 * jnp.arange(L, dtype=_I64)
    safe = jnp.where(nz, mag, 1)
    msb = jnp.max(jnp.where(nz, j32 + posit.floor_log2(safe), -1), axis=-1)

    # top 31 bits (width F+G = 30 significand + 1) starting at msb, plus
    # sticky from everything below — gathered via one-hot dots (no
    # data-dependent indexing, Pallas-friendly)
    hi = msb >> 5
    sh = msb & 31
    jj = jnp.arange(L, dtype=_I64)

    def pick(idx):
        sel = (jj == idx[..., None])
        return jnp.sum(jnp.where(sel, mag, 0), axis=-1)

    g0 = pick(hi)
    g1 = pick(hi - 1)
    r = 30 - sh                                          # bits needed from g1
    rpos = jnp.maximum(r, 0)
    # sh <= 31 so r >= -1; r == -1 means the top limb alone holds 32 bits
    sig = jnp.where(r >= 0,
                    (g0 << rpos) | (g1 >> (32 - rpos)),
                    g0 >> 1)
    st_top = jnp.where(r >= 0,
                       g1 & ((_i64(1) << (32 - rpos)) - 1),
                       (g0 & 1) | jnp.where(g1 != 0, 1, 0))
    below = jnp.any(jnp.where(jj < (hi - 1)[..., None], mag, 0) != 0, axis=-1)
    sticky = (st_top != 0) | below

    scale = msb + quire_lsb_exp(fmt)
    safe_sig = jnp.where(is_zero, _i64(1) << 30, sig)
    return posit.encode(neg, scale, safe_sig, sticky, is_zero, q.nar, fmt,
                        width=30)


# --------------------------------------------------------------------------
# fused reductions
# --------------------------------------------------------------------------

# quire_dot auto-chunking: reductions up to this K materialize (..., K, L)
# in one shot; longer ones scan K-chunks of this width (bit-identical —
# integer limb adds are associative; same budget as quire_gemm's kc).
_DOT_CHUNK = 128


def _dot_limbs(a_p, b_p, fmt: PositFormat, negate):
    """Exact limb-space contributions of sum_k a[..., k]*b[..., k]:
    materializes (..., K, L) then reduces K — right for K*L that fits
    memory (vector/matrix-vector scale); see _dot_limbs_chunked."""
    fa, ca, sga, na = _decode_half(a_p, fmt)
    fb, cb, sgb, nb = _decode_half(b_p, fmt)
    prod = fa * fb
    idx0 = _prod_idx0(ca, cb, fmt)
    sgn = sga * sgb
    sgn = jnp.where(jnp.asarray(negate, bool), -sgn, sgn)
    L = quire_limbs(fmt)
    limbs = _deposit(jnp.zeros(prod.shape + (L,), _I64), prod, idx0, sgn)
    return jnp.sum(limbs, axis=-2), jnp.any(na | nb, axis=-1)


def _dot_limbs_chunked(a_p, b_p, fmt: PositFormat, negate, kc):
    """Memory-bounded variant: scan K in chunks of ``kc``, each step
    materializing only (..., kc, L).  Bit-identical to _dot_limbs for any
    chunking (integer adds); peak memory drops K/kc-fold."""
    fa, ca, sga, na = _decode_half(a_p, fmt)
    fb, cb, sgb, nb = _decode_half(b_p, fmt)
    prod = fa * fb
    idx0 = _prod_idx0(ca, cb, fmt)
    sgn = sga * sgb
    sgn = jnp.where(jnp.asarray(negate, bool), -sgn, sgn)
    sgn = jnp.broadcast_to(sgn, prod.shape)

    k = prod.shape[-1]
    nsteps = -(-k // kc)
    pad = nsteps * kc - k
    if pad:
        widths = [(0, 0)] * (prod.ndim - 1) + [(0, pad)]
        prod = jnp.pad(prod, widths, constant_values=1)
        idx0 = jnp.pad(idx0, widths)
        sgn = jnp.pad(sgn, widths)          # sgn == 0 -> dead deposit

    # (nsteps, ..., kc) slabs for the scan
    slab = lambda x: jnp.moveaxis(
        x.reshape(x.shape[:-1] + (nsteps, kc)), -2, 0)
    L = quire_limbs(fmt)

    def step(limbs, xs):
        p, i0, sg = xs
        d = _deposit(jnp.zeros(p.shape + (L,), _I64), p, i0, sg)
        return limbs + jnp.sum(d, axis=-2), None

    limbs0 = jnp.zeros(prod.shape[:-1] + (L,), _I64)
    limbs, _ = jax.lax.scan(step, limbs0, (slab(prod), slab(idx0), slab(sgn)))
    return limbs, jnp.any(na | nb, axis=-1)


def quire_dot(a_p, b_p, fmt: PositFormat = P32E2, init_p=None, negate=False,
              kc: int | None = None):
    """Exact fused dot product over the LAST axis, one posit rounding:

        out = round( init + (-1)^negate * sum_k a[..., k] * b[..., k] )

    a_p/b_p broadcastable posit words; ``init_p`` optional posit words of
    the reduced shape (added exactly, e.g. BLAS beta=1 / residual b).
    ``kc`` bounds per-step materialization for long reductions (schedule
    only — every chunking is bit-identical); None auto-chunks past
    K = 2 * _DOT_CHUNK.
    """
    a_p, b_p = jnp.broadcast_arrays(jnp.asarray(a_p, jnp.int32),
                                    jnp.asarray(b_p, jnp.int32))
    k = a_p.shape[-1]
    if kc is None:
        kc = k if k <= 2 * _DOT_CHUNK else _DOT_CHUNK
    kc = max(1, min(int(kc), k))
    if kc >= k:
        limbs, nar = _dot_limbs(a_p, b_p, fmt, negate)
    else:
        limbs, nar = _dot_limbs_chunked(a_p, b_p, fmt, negate, kc)
    q = Quire(limbs=limbs, nar=nar)
    if init_p is not None:
        q = qadd_posit(q, jnp.broadcast_to(jnp.asarray(init_p, jnp.int32),
                                           q.shape), fmt)
    return q_to_posit(q, fmt)


def fdp(a_p, b_p, fmt: PositFormat = P32E2):
    """The posit standard's fused dot product of two 1-D posit vectors."""
    return quire_dot(a_p, b_p, fmt)


# --------------------------------------------------------------------------
# Pallas-facing 32-bit limb planes
# --------------------------------------------------------------------------

def to_limbs32(q: Quire):
    """(..., L) int64 redundant limbs -> (..., L, 2) int32 (lo, hi) planes.

    TPU Pallas kernels carry no int64; a kernel-resident quire keeps each
    radix-2^32 limb as two int32 planes — lo holds the limb's low 32 bits
    as a raw pattern, hi the (signed) high word — and accumulates chunk
    deposits with explicit carry into the hi plane (DESIGN.md §6).  This
    helper is the layout contract between the jnp quire and such kernels.
    """
    lo = jax.lax.bitcast_convert_type(
        (q.limbs & _M32).astype(jnp.uint32), jnp.int32)
    hi = (q.limbs >> 32).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1), q.nar


def from_limbs32(planes, nar) -> Quire:
    """Inverse of ``to_limbs32``."""
    lo = jax.lax.bitcast_convert_type(planes[..., 0], jnp.uint32).astype(_I64)
    hi = planes[..., 1].astype(_I64)
    return Quire(limbs=(hi << 32) | lo, nar=jnp.asarray(nar, bool))
