"""Quire-exact accumulation subsystem (posit standard fused ops).

``repro.quire`` provides the exact fixed-point fused accumulator the
posit standard pairs with every format — the accuracy lever behind the
paper's Cholesky/LU results — as branch-free, vectorized JAX:

    quire_zero / quire_from_posit / qma / qadd_posit / qneg / q_renorm
    q_to_posit                      single-rounding quire -> posit
    fdp / quire_dot                 exact fused dot products (batched)
    quire_gemm / quire_gemv         exact GEMM/GEMV (one rounding per elem)
    quire_gemm_limbs                pre-rounding limb planes (dist psum hook)
    to_limbs32 / from_limbs32       Pallas-facing int32 limb planes

See DESIGN.md §6 for the limb layout and exactness argument; §7 for the
cross-device limb-plane reduction built on ``quire_gemm_limbs``.
"""
from repro.quire.quire import (Quire, fdp, from_limbs32, q_renorm, q_to_posit,
                               qadd_posit, qma, qneg, quire_dot,
                               quire_from_posit, quire_limbs, quire_lsb_exp,
                               quire_zero, to_limbs32)
from repro.quire.gemm import quire_gemm, quire_gemm_limbs, quire_gemv

__all__ = [
    "Quire", "quire_zero", "quire_from_posit", "qma", "qadd_posit", "qneg",
    "q_renorm", "q_to_posit", "fdp", "quire_dot", "quire_gemm",
    "quire_gemm_limbs", "quire_gemv", "quire_limbs", "quire_lsb_exp",
    "to_limbs32", "from_limbs32",
]
