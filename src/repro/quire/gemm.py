"""Quire-exact GEMM: every output element is an exact fused dot product.

    C[i, j] = round( (-1)^negate * sum_k A[i, k] * B[k, j]  (+ C0[i, j]) )

with ONE posit rounding per element — the ground-truth backend behind
``kernels.ops.rgemm(..., backend="quire_exact")`` and the reference the
Pallas kernel's f32 accumulation is measured against.

The K reduction is a ``lax.scan`` carrying the (M, N, L) limb state,
**K-chunked**: each step decodes nothing (operands are decoded once,
outside the scan) and deposits ``kc`` columns' outer-product
contributions — ``kc`` fused fixed-shape int64 adds per step instead of
one, cutting the sequential scan length ``ceil(K / kc)``-fold and turning
the step body into a batch of MXU/VPU-friendly outer products; the scan
itself is additionally unrolled ``unroll``-fold, so ``kc * unroll``
columns share each (M, N, L) limb-carry round-trip.

Exactness under chunking is free: deposits are integer limb adds, so any
regrouping of the K sum is bit-identical by associativity.  Headroom is
also unchanged: every product contributes < 2^32 per limb (its three
radix-2^32 chunks land on *distinct* limbs), so K accumulated columns
bound each redundant limb by K * 2^32 — int64 safe for K < 2^31 whether
deposited one column or ``kc`` columns at a time (DESIGN.md §6.1).

Memory is O(M*N*L); wall-clock is O(K / kc) scan steps of vectorized
work, which is the correctness-vehicle trade (same contract as the
Pallas kernel's interpret mode).

``quire_gemm_limbs`` exposes the pre-rounding limb state — the reduction
currency of the distributed GEMM (repro.dist.pblas): K slabs deposited on
different devices psum in limb space and round once (DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import P32E2, PositFormat
from repro.quire.quire import (Quire, _I64, _decode_half, _deposit,
                               _prod_idx0, q_to_posit, qadd_posit,
                               quire_limbs)

# Default columns deposited per scan step and scan unroll factor.  Any
# (kc, unroll) is bit-identical (integer adds); kc=1, unroll=1 reproduces
# the PR-1 per-column scan schedule.  kc * unroll columns share one limb
# carry round-trip; (8, 4) measured fastest on CPU (bench_decomp.py) —
# big enough to amortize the (M, N, L) carry traffic, small enough that
# XLA's fusion of the step body doesn't fall over.
_KC_DEFAULT = 8
_UNROLL_DEFAULT = 4


def quire_gemm_limbs(a_p: jax.Array, b_p: jax.Array,
                     fmt: PositFormat = P32E2, negate: bool = False,
                     kc: int = _KC_DEFAULT,
                     unroll: int = _UNROLL_DEFAULT):
    """The limb-plane half of ``quire_gemm``: returns the UNROUNDED
    (M, N, L) int64 redundant limb state and (M, N) nar flags of
    sum_k (-1)^negate * A[i, k] * B[k, j].

    This is the distributed-GEMM reduction hook (repro.dist.pblas): limb
    states from disjoint K slabs held on different devices add exactly
    (integer limbs, associative), so a cross-device ``lax.psum`` of these
    planes followed by ONE ``q_to_posit`` rounding is bit-identical to a
    single-device ``quire_gemm`` over the full K — the headroom bound is
    unchanged because the psum merely reassociates the same K-term sum
    (DESIGN.md §6.1/§7).
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    b_p = jnp.asarray(b_p, jnp.int32)
    m, k = a_p.shape
    k2, n = b_p.shape
    assert k == k2, (a_p.shape, b_p.shape)
    L = quire_limbs(fmt)
    kc = max(1, min(int(kc), k))

    fa, ca, sga, na = _decode_half(a_p, fmt)             # (M, K) each
    fb, cb, sgb, nb = _decode_half(b_p, fmt)             # (K, N)
    if negate:
        sga = -sga

    # Pad K up to a chunk multiple with dead lanes (sgn == 0 -> the deposit
    # is exactly zero), then scan over (nsteps, kc, ...) slabs.
    nsteps = -(-k // kc)
    pad = nsteps * kc - k
    if pad:
        fa = jnp.pad(fa, ((0, 0), (0, pad)), constant_values=1)
        ca = jnp.pad(ca, ((0, 0), (0, pad)))
        sga = jnp.pad(sga, ((0, 0), (0, pad)))
        fb = jnp.pad(fb, ((0, pad), (0, 0)), constant_values=1)
        cb = jnp.pad(cb, ((0, pad), (0, 0)))
        sgb = jnp.pad(sgb, ((0, pad), (0, 0)))

    slab_a = lambda x: x.T.reshape(nsteps, kc, m)
    slab_b = lambda x: x.reshape(nsteps, kc, n)
    xs = (slab_a(fa), slab_a(ca), slab_a(sga),
          slab_b(fb), slab_b(cb), slab_b(sgb))

    def step(limbs, slab):
        fa_c, ca_c, sga_c, fb_c, cb_c, sgb_c = slab
        # kc outer-product deposits, unrolled at trace so XLA fuses them
        # into one kernel per scan step (amortizing the per-step dispatch
        # and carry round-trip that dominated the per-column schedule).
        for i in range(kc):
            prod = fa_c[i][:, None] * fb_c[i][None, :]   # (M, N) < 2^56
            idx0 = _prod_idx0(ca_c[i][:, None], cb_c[i][None, :], fmt)
            sgn = sga_c[i][:, None] * sgb_c[i][None, :]
            limbs = _deposit(limbs, prod, idx0, sgn)
        return limbs, None

    limbs0 = jnp.zeros((m, n, L), _I64)
    limbs, _ = jax.lax.scan(step, limbs0, xs, unroll=max(1, int(unroll)))

    nar = jnp.any(na, axis=1)[:, None] | jnp.any(nb, axis=0)[None, :]
    return limbs, nar


@functools.partial(jax.jit, static_argnames=("fmt", "negate", "kc", "unroll"))
def quire_gemm(a_p: jax.Array, b_p: jax.Array, c0_p: jax.Array | None = None,
               fmt: PositFormat = P32E2, negate: bool = False,
               kc: int = _KC_DEFAULT,
               unroll: int = _UNROLL_DEFAULT) -> jax.Array:
    """(M, K) @ (K, N) posit-word matmul, exact accumulation, one rounding.

    ``c0_p`` (optional (M, N) posit words) is added into the quire exactly
    (BLAS beta=1).  ``negate`` flips every product sign exactly (alpha=-1).
    ``kc``/``unroll`` set the K-chunk width per scan step and the scan
    unroll factor (schedule only — the result is bit-identical for every
    choice).
    """
    limbs, nar = quire_gemm_limbs(a_p, b_p, fmt, negate, kc, unroll)
    q = Quire(limbs=limbs, nar=nar)
    if c0_p is not None:
        q = qadd_posit(q, jnp.asarray(c0_p, jnp.int32), fmt)
    return q_to_posit(q, fmt)


@functools.partial(jax.jit, static_argnames=("fmt", "negate", "kc",
                                             "unroll"))
def quire_gemv(a_p: jax.Array, x_p: jax.Array, c0_p: jax.Array | None = None,
               fmt: PositFormat = P32E2, negate: bool = False,
               kc: int = _KC_DEFAULT,
               unroll: int = _UNROLL_DEFAULT) -> jax.Array:
    """(M, K) @ (K,) posit-word matvec, exact accumulation, one rounding
    per component — ``quire_gemm`` with a single column, same K-chunked
    deposit scan and the same exactness argument.

    The residual shape of the least-squares solvers (lapack/qr.py): the
    semi-normal correction's A^T r is one ``quire_gemv`` per sweep, and
    any chunking is bit-identical to ``quire_dot`` over the same rows
    (integer limb adds, associative).
    """
    limbs, nar = quire_gemm_limbs(a_p, jnp.asarray(x_p, jnp.int32)[:, None],
                                  fmt, negate, kc, unroll)
    q = Quire(limbs=limbs[:, 0, :], nar=nar[:, 0])
    if c0_p is not None:
        q = qadd_posit(q, jnp.asarray(c0_p, jnp.int32), fmt)
    return q_to_posit(q, fmt)
