"""Quire-exact GEMM: every output element is an exact fused dot product.

    C[i, j] = round( (-1)^negate * sum_k A[i, k] * B[k, j]  (+ C0[i, j]) )

with ONE posit rounding per element — the ground-truth backend behind
``kernels.ops.rgemm(..., backend="quire_exact")`` and the reference the
Pallas kernel's f32 accumulation is measured against.

The K reduction is a ``lax.scan`` carrying the (M, N, L) limb state: each
step decodes one A column / B row (decoded once, outside the scan) and
deposits the outer product's 3-chunk contributions — a fixed-shape int64
add per step, the software shape of a tile-resident hardware quire
(DESIGN.md §6).  Memory is O(M*N*L); wall-clock is O(K) scan steps of
vectorized work, which is the correctness-vehicle trade (same contract as
the Pallas kernel's interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import P32E2, PositFormat
from repro.quire.quire import (Quire, _I64, _decode_half, _deposit,
                               _prod_idx0, q_to_posit, qadd_posit,
                               quire_limbs)


@functools.partial(jax.jit, static_argnames=("fmt", "negate"))
def quire_gemm(a_p: jax.Array, b_p: jax.Array, c0_p: jax.Array | None = None,
               fmt: PositFormat = P32E2, negate: bool = False) -> jax.Array:
    """(M, K) @ (K, N) posit-word matmul, exact accumulation, one rounding.

    ``c0_p`` (optional (M, N) posit words) is added into the quire exactly
    (BLAS beta=1).  ``negate`` flips every product sign exactly (alpha=-1).
    """
    a_p = jnp.asarray(a_p, jnp.int32)
    b_p = jnp.asarray(b_p, jnp.int32)
    m, k = a_p.shape
    k2, n = b_p.shape
    assert k == k2, (a_p.shape, b_p.shape)
    L = quire_limbs(fmt)

    fa, ca, sga, na = _decode_half(a_p, fmt)             # (M, K) each
    fb, cb, sgb, nb = _decode_half(b_p, fmt)             # (K, N)
    if negate:
        sga = -sga

    def step(carry, xs):
        limbs = carry
        fa_k, ca_k, sga_k, fb_k, cb_k, sgb_k = xs        # (M,) and (N,)
        prod = fa_k[:, None] * fb_k[None, :]             # (M, N) < 2^56
        idx0 = _prod_idx0(ca_k[:, None], cb_k[None, :], fmt)
        sgn = sga_k[:, None] * sgb_k[None, :]
        return _deposit(limbs, prod, idx0, sgn), None

    limbs0 = jnp.zeros((m, n, L), _I64)
    xs = (fa.T, ca.T, sga.T, fb, cb, sgb)                # scan over K
    limbs, _ = jax.lax.scan(step, limbs0, xs)

    nar = jnp.any(na, axis=1)[:, None] | jnp.any(nb, axis=0)[None, :]
    q = Quire(limbs=limbs, nar=nar)
    if c0_p is not None:
        q = qadd_posit(q, jnp.asarray(c0_p, jnp.int32), fmt)
    return q_to_posit(q, fmt)
