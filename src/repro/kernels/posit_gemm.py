"""Pallas TPU kernel: format-parametric posit GEMM via MXU hi/lo-split.

TPU adaptation of the paper's accelerators (DESIGN.md §2):

* The FPGA design surrounds each systolic MAC with combinational posit
  decode/encode.  The MXU is a systolic array too, but it consumes floats —
  so the TPU-native dataflow is *decode once per VMEM tile -> matmul on the
  MXU -> encode once per output tile*.
* A decoded Posit(32,2) significand has 28 bits; float32 carries 24.  We
  split each decoded value exactly as ``x = hi + lo`` (hi: top 24 bits,
  lo: bottom 4 bits) and compute ``A@B = Ah@Bh + (Ah@Bl + Al@Bh)`` in three
  MXU passes with f32 accumulation — the same splitting the paper discusses
  for tensor cores (Ootomo & Yokota [28], cited in §6.3), adapted to posit
  decode.  The ``Al@Bl`` term is < 2^-48 relative and is dropped.
* ``mode="split3_comp"`` adds tile-level Knuth TwoSum compensation of the
  K-loop accumulation (error ~ one f32 rounding per *tile* instead of per
  K step), at ~6 VPU flops per output element per K tile — noise next to
  the 3 MXU passes.

``posit_gemm`` fuses the single posit rounding (quire-lite semantics, see
kernels/ref.py) into the final-k grid step: the last ``@pl.when`` block
encodes the f32 accumulator to Posit(32,2) words in-kernel
(``encode_p32_f32`` — pure int32/f32 ops, the mirror of
``decode_split_f32``) and writes an int32 ``o_ref``, so the posit result
never round-trips through HBM as f32 and ops.py consumes words directly.
``posit_gemm_f32`` keeps the raw-accumulator output for general
alpha/beta epilogues and accuracy studies.

Exactness domain: the hi/lo split is exact for |x| >= 2^-99 (lo's exponent
reaches f32's normal floor at scale-27 = -126); below that lo flushes to 0
— matching TPU subnormal-flush semantics — with relative error < 2^-24,
far outside the paper's golden zone and below binary32's own epsilon.

**Format parameterization** (DESIGN.md §8): decode and encode are one
field-space implementation over ``PositFormat`` — every per-format number
(regime alignment shift, es field width, maxpos/NaR patterns) is a static
Python constant folded at trace time, so the traced kernel for p32e2 is
op-for-op the pre-parametric kernel (pinned by the golden tests) and
narrower formats get the same branch-free dataflow for free.  For
p16e1/p8e2 the decoded significand carries <= 13 bits, so the hi plane
alone is exact and the lo-plane MXU passes multiply zeros — correct, if
wasteful; a skip-lo fast path is future work.  ``encode_p16_f32`` /
``encode_p32_f32`` are the named per-format epilogue entry points
(bit-identical to ``posit.from_float32_bits`` per format, pinned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.formats import P16E1, P32E2, PositFormat

try:  # TPU-specific pieces; interpret mode works without a TPU backend.
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

_NAN = np.float32(np.nan)


# --------------------------------------------------------------------------
# in-kernel int32 posit decode -> (hi, lo) f32 split
# --------------------------------------------------------------------------

def _floor_log2_i32(x):
    """floor(log2(x)) for x > 0, int32, 5 fixed binary-search steps."""
    r = jnp.zeros_like(x)
    for s in (16, 8, 4, 2, 1):
        t = x >> s
        big = t > 0
        x = jnp.where(big, t, x)
        r = r + jnp.where(big, s, 0)
    return r


def _pow2_f32(e):
    """2.0**e as f32 via exponent-field construction; caller masks e < -126."""
    bits = (jnp.clip(e + 127, 1, 254) << 23).astype(jnp.int32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def decode_split_f32(p, fmt: PositFormat = P32E2):
    """int32 posit words -> (hi, lo) f32 with hi+lo == value exactly
    (for |value| >= 2^-99; see module docstring).  Pure int32/f32 ops —
    legal inside a Pallas TPU kernel body.  Format-parametric: alignment
    shifts and field widths are static per-format constants; the decoded
    significand is normalized to the shared 28-bit working width (bits
    below the format's fraction field are zero), so the hi/lo split and
    every downstream op are format-independent."""
    nbits, es = fmt.nbits, fmt.es
    is_zero = p == 0
    is_nar = p == np.int32(fmt.nar_pattern)
    signbit = p < 0
    a = jnp.where(signbit, jnp.int32(0) - p, p)          # 2's-complement abs
    body = a << (33 - nbits)                             # regime MSB at bit31
    r0 = body < 0
    y = jnp.where(r0, ~body, body)                       # bit31 == 0 now
    y_safe = jnp.where(y == 0, 1, y)
    m = 31 - _floor_log2_i32(y_safe)                     # regime run length
    k = jnp.where(r0, m - 1, -m)
    u = (body << m) << 1                                 # strip regime+term
    e = (u >> (32 - es)) & ((1 << es) - 1) if es else jnp.zeros_like(u)
    frac = u << es                                       # frac MSB at bit31
    sig = (1 << 27) | ((frac >> 5) & ((1 << 27) - 1))    # 28-bit significand
    scale = (k << es) + e

    sgn = jnp.where(signbit, jnp.float32(-1.0), jnp.float32(1.0))
    dead = is_zero | is_nar
    ph = jnp.where((scale - 23 >= -126) & ~dead, _pow2_f32(scale - 23), 0.0)
    plo = jnp.where((scale - 27 >= -126) & ~dead, _pow2_f32(scale - 27), 0.0)
    hi = (sig >> 4).astype(jnp.float32) * ph * sgn
    lo = (sig & 15).astype(jnp.float32) * plo * sgn
    hi = jnp.where(is_nar, _NAN, hi)
    return hi, lo


# --------------------------------------------------------------------------
# in-kernel f32 -> posit encode (the epilogue mirror of decode_split_f32)
# --------------------------------------------------------------------------

def encode_posit_f32(x, fmt: PositFormat = P32E2):
    """f32 values -> int32 posit words, pure int32 ops — legal inside a
    Pallas TPU kernel body.  Bit-identical to ``posit.from_float32_bits``
    for every registered format (pinned by tests): correctly rounds the
    f32 value to the posit lattice with RNE ties to the even *pattern*.

    The pattern is assembled directly — ``regime << avail | [e|frac]`` —
    so the tie check reads the true pattern LSB (an [e|frac] bit normally,
    the regime terminator in the long-regime fringe) and a round-up that
    crosses a regime boundary is plain integer +1 on the monotone pattern.
    All field widths (``es + 23``-bit [e|frac], ``nbits - 1`` pattern
    bits, max_scale clamps) are static per-format constants.
    """
    nbits, es = fmt.nbits, fmt.es
    ms = fmt.max_scale
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    sign = bits < 0
    expf = (bits >> 23) & 0xFF
    man = bits & 0x7FFFFF
    is_zero = (expf == 0) & (man == 0)
    is_nar = expf == 255                                 # inf/NaN -> NaR
    # f32 subnormals (< 2^-126) sit below every format's minpos.
    scale = jnp.where(expf == 0, jnp.int32(-150), expf - 127)
    over = scale >= ms                                   # k_max regime: maxpos
    under = (scale < -ms) & ~is_zero
    sc = jnp.clip(scale, -ms, ms - 1)                    # shift-safe lanes

    k = sc >> es                                         # floor(scale / 2^es)
    e = sc & ((1 << es) - 1)
    reg_len = jnp.where(k >= 0, k + 2, 1 - k)            # field w/ terminator
    avail = (nbits - 1) - reg_len                        # room for [e|frac]
    regime = jnp.where(k >= 0,
                       ((jnp.int32(1) << (k + 1)) - 1) << 1, jnp.int32(1))
    ef = (jnp.int32(1) << (es + 23)) | (e << 23) | man   # [1|e|frac23]
    d = jnp.maximum((es + 23) - avail, 0)                # [e|frac] bits dropped
    shl = jnp.maximum(avail - (es + 23), 0)              # or left-padded
    kf = (ef >> d) - (jnp.int32(1) << ((es + 23) - d))   # strip hidden bit
    pat0 = (regime << avail) | (kf << shl)
    dropped = ef & ((jnp.int32(1) << d) - 1)
    half = (jnp.int32(1) << d) >> 1
    rnd = (dropped > half) | ((dropped == half) & (dropped != 0)
                             & ((pat0 & 1) == 1))
    pat = pat0 + rnd.astype(jnp.int32)

    pat = jnp.where(over, jnp.int32(fmt.maxpos_pattern), pat)  # never NaR
    pat = jnp.where(under, jnp.int32(1), pat)            # clamp at minpos
    out = jnp.where(sign, jnp.int32(0) - pat, pat)       # 2's-complement neg
    out = jnp.where(is_zero, 0, out)
    return jnp.where(is_nar, np.int32(fmt.nar_pattern), out)


def encode_p32_f32(x):
    """f32 -> Posit(32,2) words (the PR-2 epilogue, now a specialization)."""
    return encode_posit_f32(x, P32E2)


def encode_p16_f32(x):
    """f32 -> Posit(16,1) words — the mixed-precision factorization
    format's in-kernel epilogue (p16e1 significands carry <= 13 bits, so
    the f32 accumulator holds them exactly and this rounding is the only
    one)."""
    return encode_posit_f32(x, P16E1)


# --------------------------------------------------------------------------
# kernel body
# --------------------------------------------------------------------------

def _matmul_f32(x, y):
    return jax.lax.dot_general(
        x, y, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel(a_ref, b_ref, o_ref, acc_ref, err_ref, *, n_k, compensated,
            emit_posit, negate, fmt):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if compensated:
            err_ref[...] = jnp.zeros_like(err_ref)

    ah, al = decode_split_f32(a_ref[...], fmt)
    bh, bl = decode_split_f32(b_ref[...], fmt)
    partial = _matmul_f32(ah, bh) + (_matmul_f32(ah, bl) + _matmul_f32(al, bh))

    if compensated:
        acc = acc_ref[...]
        s = acc + partial
        bp = s - acc                                   # Knuth TwoSum
        err_ref[...] += (acc - (s - bp)) + (partial - bp)
        acc_ref[...] = s
    else:
        acc_ref[...] += partial

    @pl.when(k_idx == n_k - 1)
    def _done():
        val = acc_ref[...] + err_ref[...] if compensated else acc_ref[...]
        if negate:
            val = -val                                 # exact f32 sign flip
        if emit_posit:
            o_ref[...] = encode_posit_f32(val, fmt)    # fused epilogue
        else:
            o_ref[...] = val


# --------------------------------------------------------------------------
# pallas_call wrappers
# --------------------------------------------------------------------------

def _resolve_interpret(interpret):
    """Satellite fix: ``interpret=None`` auto-detects — compile the kernel
    on a real TPU backend, fall back to interpret mode elsewhere (CPU/GPU
    validation), so callers never thread the flag."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _posit_gemm_call(a_p, b_p, *, bm, bn, bk, mode, interpret, emit_posit,
                     negate, fmt):
    m, k = a_p.shape
    k2, n = b_p.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n), (bm, bn, bk))
    compensated = {"split3": False, "split3_comp": True}[mode]
    interpret = _resolve_interpret(interpret)
    n_k = k // bk

    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(_kernel, n_k=n_k, compensated=compensated,
                               emit_posit=emit_posit, negate=negate, fmt=fmt)
    scratch = [_VMEM((bm, bn), jnp.float32), _VMEM((bm, bn), jnp.float32)]
    out_dtype = jnp.int32 if emit_posit else jnp.float32

    kwargs = {}
    if pltpu is not None and not interpret:
        params_cls = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(a_p, b_p)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "mode",
                                             "interpret", "fmt"))
def posit_gemm_f32(a_p: jax.Array, b_p: jax.Array, *, bm: int = 128,
                   bn: int = 128, bk: int = 128, mode: str = "split3",
                   interpret: bool | None = None,
                   fmt: PositFormat = P32E2) -> jax.Array:
    """(M,K) @ (K,N) over int32 posit words -> f32 accumulator.

    M, N, K must be multiples of the (MXU-aligned) block sizes; ops.py pads.
    ``interpret=None`` auto-detects (compiled on TPU, Python interpreter
    elsewhere); pass True/False to force.  ``fmt`` selects the posit
    format of the input words (static; constants fold at trace).
    """
    return _posit_gemm_call(a_p, b_p, bm=bm, bn=bn, bk=bk, mode=mode,
                            interpret=interpret, emit_posit=False,
                            negate=False, fmt=fmt)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "mode",
                                             "negate", "interpret", "fmt"))
def posit_gemm(a_p: jax.Array, b_p: jax.Array, *, bm: int = 128,
               bn: int = 128, bk: int = 128, mode: str = "split3",
               negate: bool = False, interpret: bool | None = None,
               fmt: PositFormat = P32E2) -> jax.Array:
    """(M,K) @ (K,N) posit words -> posit words, encode fused in-kernel.

    The final-k ``@pl.when`` block rounds the f32 accumulator to the posit
    format inside the kernel (one rounding, quire-lite semantics) and
    emits int32 words — no f32 HBM round-trip, no host epilogue.
    ``negate`` flips the sign before the encode (exact), serving the BLAS
    alpha=-1 form.  Bit-identical to
    ``from_float32_bits(±posit_gemm_f32(...), fmt)`` for every format.
    """
    return _posit_gemm_call(a_p, b_p, bm=bm, bn=bn, bk=bk, mode=mode,
                            interpret=interpret, emit_posit=True,
                            negate=negate, fmt=fmt)
