"""Rgemm — BLAS-3 GEMM interface over posit words (MPLAPACK naming).

    C = alpha * op(A) @ op(B) + beta * C,   op in {identity, transpose}

Transposes are applied at the op level before the kernel, mirroring the
paper's FPGA flow ("we transpose input matrices on a host CPU before
sending them to the FPGA").  Backends:

* ``pallas_split3`` / ``pallas_split3_comp`` — the TPU kernel
  (kernels/posit_gemm.py), f32 accumulators, single posit rounding
  (quire-lite semantics).  For alpha in {1, -1} and beta = 0 the rounding
  is fused into the kernel's final-k step (int32 posit words come
  straight off the kernel — DESIGN.md §2.1); other alpha/beta use the
  f32-accumulator output with a host f64 epilogue.  Interpret mode on
  CPU, compiled on TPU (auto-detected).
* ``xla_quire``   — decode->f64 dot->encode (same semantics, no Pallas);
  the fast CPU path used by the decomposition benchmarks.
* ``quire_exact`` — true posit-standard quire (repro.quire): exact
  fixed-point accumulation, ONE rounding per output element.  For
  alpha in {1, -1} and beta in {0, 1} the whole update is a single fused
  op (products negated exactly, beta*C added into the quire exactly) —
  exactly the trailing-update shape Rpotrf/Rgetrf issue.  Other
  alpha/beta are folded in with one pre-rounded posit scaling.
* ``faithful``    — per-MAC posit rounding in BLAS chain order (the
  paper's PE behaviour): C(:,j) starts at beta*C, accumulates
  alpha*B(l,j)*A(:,l) with every op rounded.  Ground truth for accuracy
  studies.

Beta semantics: beta == 0 means C is NOT referenced (BLAS convention —
C may hold garbage or NaR) on every backend except ``faithful``, whose
literal per-op chain computes 0 * C first (the paper's PE op order, so
NaR in C poisons the output there).

``fmt`` selects the posit format (static, default Posit(32,2)): every
backend — including the Pallas kernel's in-kernel decode/encode — runs
the same dataflow with the format's field constants folded at trace time
(DESIGN.md §8).  All operands and the result are words of that ONE
format; mixed-format GEMM is done by converting at the boundary
(``posit.pconvert``), never inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P32E2, PositFormat
from repro.kernels import ref
from repro.kernels.posit_gemm import posit_gemm, posit_gemm_f32
from repro.obs import metrics as _obs_metrics
from repro.obs import numerics as _obs_numerics
from repro.obs import trace as _obs_trace
from repro.quire import quire_gemm

_ZERO = jnp.int32(0)


def _pad_to(x, mult, axes):
    pads = [(0, 0)] * x.ndim
    needs = False
    for ax in axes:
        r = (-x.shape[ax]) % mult
        if r:
            pads[ax] = (0, r)
            needs = True
    return jnp.pad(x, pads) if needs else x


def _scalar_posit(x, fmt: PositFormat):
    """alpha/beta are static Python scalars -> posit words at trace time."""
    assert isinstance(x, (int, float)), (
        "alpha/beta must be static Python scalars")
    return posit.from_float64(jnp.float64(x), fmt)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "trans_a",
                                             "trans_b", "backend", "block",
                                             "fmt"))
def _rgemm_jit(a_p: jax.Array, b_p: jax.Array, c_p: jax.Array | None = None,
               alpha=1.0, beta=0.0, *, trans_a: bool = False,
               trans_b: bool = False, backend: str = "xla_quire",
               block: int = 128, fmt: PositFormat = P32E2) -> jax.Array:
    """The jitted GEMM program (see ``rgemm``, the public entry point)."""
    a_p = jnp.asarray(a_p, jnp.int32)
    b_p = jnp.asarray(b_p, jnp.int32)
    if trans_a:
        a_p = a_p.T
    if trans_b:
        b_p = b_p.T
    m, k = a_p.shape
    _, n = b_p.shape
    alpha_p = _scalar_posit(alpha, fmt)
    beta_p = _scalar_posit(beta, fmt)
    if c_p is None:
        c_p = jnp.zeros((m, n), jnp.int32)

    if backend == "quire_exact":
        # Fold alpha/beta so the common BLAS-3 updates stay single-rounding:
        # |alpha| == 1 -> exact product negation; beta == 1 -> exact quire
        # add of C; anything else costs one pre-rounded posit scaling.
        a_in = a_p
        if alpha not in (1.0, -1.0, 1, -1):
            a_in = posit.mul(alpha_p, a_p, fmt, backend="fast")
        if beta in (0.0, 0):
            c_in = None
        elif beta in (1.0, 1):
            c_in = c_p
        else:
            c_in = posit.mul(beta_p, c_p, fmt, backend="fast")
        return quire_gemm(a_in, b_p, c_in, fmt,
                          negate=alpha in (-1.0, -1))

    if backend == "faithful":
        # BLAS chain order: C0 = beta*C; accumulate alpha*B(l,j) * A(:,l).
        b_scaled = posit.mul(alpha_p, b_p, fmt, backend="fast")
        c0 = posit.mul(beta_p, c_p, fmt, backend="fast")
        return ref.rgemm_faithful_chain(a_p, b_scaled, c0, fmt)

    if backend == "xla_quire":
        ab = jnp.dot(posit.to_float64(a_p, fmt), posit.to_float64(b_p, fmt),
                     precision=jax.lax.Precision.HIGHEST)
    elif backend in ("pallas_split3", "pallas_split3_comp"):
        mode = backend.removeprefix("pallas_")
        ap = _pad_to(a_p, block, (0, 1))
        bp = _pad_to(b_p, block, (0, 1))
        if alpha in (1.0, 1, -1.0, -1) and beta in (0.0, 0):
            # Fused epilogue: the kernel's final-k step encodes the f32
            # accumulator to posit words in-VMEM (alpha=-1 as an exact
            # in-kernel sign flip), so rgemm consumes int32 words straight
            # off the kernel — no O(M*N) f32 HBM round-trip + host encode.
            return posit_gemm(ap, bp, bm=block, bn=block, bk=block,
                              mode=mode, fmt=fmt,
                              negate=alpha in (-1.0, -1))[:m, :n]
        ab = posit_gemm_f32(ap, bp, bm=block, bn=block, bk=block,
                            mode=mode, fmt=fmt)[:m, :n].astype(jnp.float64)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    if beta in (0.0, 0):
        # BLAS convention: beta == 0 means C is NOT referenced (it may
        # hold garbage/NaR), matching the quire_exact and fused-pallas
        # paths.  'faithful' keeps its literal per-op chain (0 * NaR =
        # NaR) since it models the paper's PE op-for-op.
        out = posit.to_float64(alpha_p, fmt) * ab
    else:
        out = (posit.to_float64(alpha_p, fmt) * ab
               + posit.to_float64(beta_p, fmt) * posit.to_float64(c_p, fmt))
    return posit.from_float64(out, fmt)


def rgemm(a_p: jax.Array, b_p: jax.Array, c_p: jax.Array | None = None,
          alpha=1.0, beta=0.0, *, trans_a: bool = False, trans_b: bool = False,
          backend: str = "xla_quire", block: int = 128,
          fmt: PositFormat = P32E2) -> jax.Array:
    """Posit GEMM returning posit words (int32) in format ``fmt``.

    Observability (repro.obs): with a collector open and CONCRETE
    operands, the call is wrapped in a span and the operand/result words
    are summarized (golden-zone occupancy, regime widths).  With no
    collector — or when this call is being traced into an outer jitted
    program (decomp/qr/pblas bodies), where the operands are tracers —
    the gate is resolved at the Python level and the exact same jitted
    program as before dispatches, so lowered programs are unchanged.
    """
    if not _obs_numerics.active(a_p, b_p, c_p if c_p is not None else a_p):
        return _rgemm_jit(a_p, b_p, c_p, alpha, beta, trans_a=trans_a,
                          trans_b=trans_b, backend=backend, block=block,
                          fmt=fmt)
    m = a_p.shape[1] if trans_a else a_p.shape[0]
    k = a_p.shape[0] if trans_a else a_p.shape[1]
    n = b_p.shape[0] if trans_b else b_p.shape[1]
    with _obs_trace.span("rgemm", m=int(m), k=int(k), n=int(n),
                         backend=backend, fmt=fmt.name):
        out = _rgemm_jit(a_p, b_p, c_p, alpha, beta, trans_a=trans_a,
                         trans_b=trans_b, backend=backend, block=block,
                         fmt=fmt)
        _obs_metrics.inc("rgemm.calls")
        _obs_metrics.inc("rgemm.macs", float(m) * float(k) * float(n))
        _obs_numerics.record_numerics("rgemm.a", a_p, fmt)
        _obs_numerics.record_numerics("rgemm.out", out, fmt)
    return out


def rgemm_f32(a_p, b_p, fmt: PositFormat = P32E2, **kw):
    """Convenience: decoded-f32 result (no final posit rounding)."""
    return posit.to_float64(rgemm(a_p, b_p, fmt=fmt, **kw),
                            fmt).astype(jnp.float32)
