"""Pure-jnp oracles for the posit GEMM kernel.

Two reference semantics, both over int32 posit-word matrices:

* ``rgemm_faithful`` — the paper's PE semantics (Flo-Posit systolic MAC /
  SoftPosit GPU kernel): every multiply rounds to posit, every accumulate
  add rounds to posit, in a fixed K-ordered chain.  This is the
  paper-faithful baseline used by the accuracy studies.
* ``rgemm_quire`` — quire-lite semantics: exact products accumulated in
  float64 (exact for p32e2: products need <= 56 bits and f64 sums of
  those are near-exact), rounded to posit ONCE at the end.  This is the
  semantic target of the TPU kernel's hi/lo-split MXU path.

The full BLAS-3 interface C = alpha*op(A)op(B) + beta*C is provided by
``repro.kernels.ops``; these oracles compute op(A)op(B) for op = identity
(transposes are applied by the wrapper, mirroring the paper's FPGA design
which transposes on the host CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P32E2, PositFormat


def rgemm_faithful_chain(a_p: jax.Array, b_p: jax.Array,
                         c0_p: jax.Array | None = None,
                         fmt: PositFormat = P32E2) -> jax.Array:
    """(M,K) x (K,N) posit-word matmul with per-MAC posit rounding.

    Accumulation starts from ``c0_p`` (BLAS: beta*C) and runs k = 0..K-1
    (the systolic array's chain order).
    """
    m, k = a_p.shape
    k2, n = b_p.shape
    assert k == k2, (a_p.shape, b_p.shape)
    if c0_p is None:
        c0_p = jnp.zeros((m, n), jnp.int32)

    def step(c_acc, ab_k):
        a_col, b_row = ab_k                       # (M,), (N,)
        prod = posit.mul(a_col[:, None], b_row[None, :], fmt, backend="fast")
        c_acc = posit.add(c_acc, prod, fmt, backend="fast")
        return c_acc, None

    c, _ = jax.lax.scan(step, c0_p, (a_p.T, b_p))
    return c


def rgemm_faithful(a_p: jax.Array, b_p: jax.Array,
                   fmt: PositFormat = P32E2) -> jax.Array:
    return rgemm_faithful_chain(a_p, b_p, None, fmt)


def rgemm_quire(a_p: jax.Array, b_p: jax.Array,
                fmt: PositFormat = P32E2) -> jax.Array:
    """Exact-products f64 accumulation, single posit rounding at the end."""
    a = posit.to_float64(a_p, fmt)
    b = posit.to_float64(b_p, fmt)
    c = jnp.dot(a, b, precision=jax.lax.Precision.HIGHEST)
    return posit.from_float64(c, fmt)


def gemm_f32_ref(a_p: jax.Array, b_p: jax.Array,
                 fmt: PositFormat = P32E2) -> jax.Array:
    """binary32 comparison path: decode to f32, f32 matmul, f32 out."""
    a = posit.to_float64(a_p, fmt).astype(jnp.float32)
    b = posit.to_float64(b_p, fmt).astype(jnp.float32)
    return jnp.dot(a, b, precision=jax.lax.Precision.HIGHEST)
