"""Branch-free, vectorized Posit(n, es) arithmetic in pure JAX.

This is the paper's core mechanism (SoftPosit [19] ported to an accelerator),
adapted to the TPU execution model:

* The paper's GPU port keeps SoftPosit's *data-dependent loops* for the regime
  decode, which costs 2.1x extra instructions + branch divergence outside the
  golden zone (paper Tables 2-3).  TPU vector units are lockstep SIMD with no
  per-lane control flow at all, so here every op is a **fixed-length,
  branch-free integer dataflow** (priority-encoder arithmetic instead of
  while-loops) — the software analogue of the paper's FPGA combinational
  decode, which makes op cost magnitude-independent *by construction*.
* All ops are exact (bit-for-bit round-to-nearest-even on the variable-width
  fraction boundary, saturation at +-maxpos, single NaR), matching SoftPosit
  semantics.  The working integer width is int64; the Pallas kernels use a
  narrower int32/f32 dataflow (see ``repro.kernels``).

Two backends share one public API:
  * ``backend="exact"`` — int64 significand arithmetic, the ground truth.
  * ``backend="fast"``  — decode to float64 (exact: p32e2 has <= 28-bit
    significands and |scale| <= 120), operate in f64, re-round.  Mul is still
    bit-exact (<= 56-bit products are exact in f64); add/div admit a
    double-rounding corner with probability ~2^-26 per op, which is
    immaterial for the accuracy *benchmarks* (they measure digits of backward
    error).  The property tests pin the exact backend against a pure-Python
    rational-arithmetic oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import P32E2, PositFormat, get_format

jax.config.update("jax_enable_x64", True)

# Working significand layout: 1.f normalized to [2^F, 2^{F+1}).
# F must hold the widest posit fraction (27 bits for p32e2) exactly.
_F = 27
# Guard bits appended for alignment/rounding inside add/div/sqrt.
_G = 3
_I64 = jnp.int64
_MASK63 = (1 << 63) - 1


def _i64(x):
    return jnp.asarray(x, dtype=_I64)


# --------------------------------------------------------------------------
# bit utilities (fixed-depth, vectorized)
# --------------------------------------------------------------------------

def floor_log2(x):
    """floor(log2(x)) for x > 0 (int64), 6 fixed binary-search steps."""
    x = _i64(x)
    r = jnp.zeros_like(x)
    for s in (32, 16, 8, 4, 2, 1):
        t = x >> s
        big = t > 0
        x = jnp.where(big, t, x)
        r = r + jnp.where(big, s, 0)
    return r


def _lsr64(x, n):
    """Logical shift right on int64 with all operands guaranteed bit63==0."""
    return x >> n  # arithmetic == logical because x >= 0 by construction


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def decode(p, fmt: PositFormat = P32E2):
    """Decode sign-extended int32 patterns into (is_zero, is_nar, sign,
    scale, sig) with sig in [2^F, 2^{F+1}) — exact for every posit <= 32 bits.
    """
    p = jnp.asarray(p, dtype=jnp.int32)
    nbits = fmt.nbits
    is_zero = p == 0
    is_nar = p == fmt.nar_pattern
    sign = p < 0
    a = _i64(jnp.where(sign, -p.astype(_I64), p.astype(_I64)))

    # Align pattern body (bits nbits-2 .. 0) with its MSB at bit 62.
    body = (a << (64 - nbits)) & _MASK63
    r0 = (body >> 62) & 1
    y = jnp.where(r0 == 1, (~body) & _MASK63, body)
    # Run length of identical leading bits within bits 62..0.
    # y has bit63 == 0 so clz64(y) = 63 - floor_log2(y); guard y == 0
    # (cannot happen for valid nonzero patterns, but keep it total).
    safe_y = jnp.where(y == 0, 1, y)
    m = jnp.where(y == 0, 62, 62 - floor_log2(safe_y))  # clamped: zero lane is
    k = jnp.where(r0 == 1, m - 1, -m)                   # overridden by is_zero

    # Strip regime + terminator; the remainder is [e | f] left-aligned at 62.
    u = (body << (m + 1)) & _MASK63
    es = fmt.es
    if es > 0:
        e = _lsr64(u, 63 - es)
        f_al = (u << es) & _MASK63
    else:
        e = jnp.zeros_like(u)
        f_al = u
    scale = (k << es) + e
    sig = (_i64(1) << _F) | _lsr64(f_al, 63 - _F)
    return is_zero, is_nar, sign, scale, sig


# --------------------------------------------------------------------------
# encode (pack-and-round; carry through the regime boundary is exact because
# posit patterns are monotone in value — see DESIGN.md §3.1)
# --------------------------------------------------------------------------

def encode(sign, scale, sig, sticky, is_zero, is_nar, fmt: PositFormat = P32E2,
           width: int = _F):
    """Round-to-nearest-even encode of (-1)^sign * sig * 2^(scale - width),
    with sig in [2^width, 2^{width+1}) and ``sticky`` = dropped-bits-nonzero.

    Saturates at +-maxpos (posits never overflow to NaR) and never rounds a
    nonzero value to zero (underflow clamps at minpos).
    """
    nbits, es = fmt.nbits, fmt.es
    scale = _i64(scale)
    sig = _i64(sig)
    sticky = jnp.asarray(sticky, dtype=bool)

    over = scale > fmt.max_scale
    under = scale < -fmt.max_scale
    # Clamp so the shift arithmetic below stays in range even for the
    # saturated lanes (their value is overridden at the end).
    scale_c = jnp.clip(scale, -fmt.max_scale, fmt.max_scale)

    k = scale_c >> es
    e = scale_c - (k << es)
    reg_len = jnp.where(k >= 0, k + 2, 1 - k)          # field width w/ terminator
    regime_val = jnp.where(k >= 0, ((_i64(1) << (k + 1)) - 1) << 1, _i64(1))

    frac = sig & ((_i64(1) << width) - 1)
    # Pre-drop low fraction bits into sticky so the packed field fits int64
    # even at the longest regime (reg_len + es + width can reach 64 bits).
    L = reg_len + es + width
    pre = jnp.maximum(L - 59, 0)
    sticky = sticky | ((frac & ((_i64(1) << pre) - 1)) != 0)
    frac = frac >> pre
    w2 = width - pre
    # One always-zero guard bit at the bottom keeps shift >= 1 below.
    body = ((((regime_val << es | e) << w2) | frac) << 1)
    shift = (L - pre) - (nbits - 1) + 1                 # >= 1 for all formats
    kept = body >> shift
    rem = body & ((_i64(1) << shift) - 1)
    half = _i64(1) << (shift - 1)
    rnd = (rem > half) | ((rem == half) & (sticky | ((kept & 1) == 1)))
    pat = kept + rnd.astype(_I64)

    pat = jnp.minimum(pat, fmt.maxpos_pattern)
    pat = jnp.where(over, fmt.maxpos_pattern, pat)
    pat = jnp.where(under, fmt.minpos_pattern, pat)
    out = jnp.where(jnp.asarray(sign, bool), -pat, pat)
    out = jnp.where(is_zero, 0, out)
    out = jnp.where(is_nar, fmt.nar_pattern, out)
    return out.astype(jnp.int32)


# --------------------------------------------------------------------------
# arithmetic — exact backend
# --------------------------------------------------------------------------

def _normalize(mag, sticky):
    """Normalize mag > 0 to [2^(F+G), 2^(F+G+1)) tracking sticky; returns
    (sig, sticky) at width F+G.  mag == 0 handled by caller."""
    W = _F + _G
    safe = jnp.where(mag == 0, 1, mag)
    msb = floor_log2(safe)
    dl = W - msb                       # left shift if positive
    left = jnp.maximum(dl, 0)
    right = jnp.maximum(-dl, 0)        # right shift at most a few bits
    lost = mag & ((_i64(1) << right) - 1)
    sig = jnp.where(dl >= 0, mag << left, mag >> right)
    sticky = sticky | (lost != 0)
    return sig, sticky, msb


def add_(a, b, fmt: PositFormat = P32E2):
    za, na, sa, ca, fa = decode(a, fmt)
    zb, nb, sb, cb, fb = decode(b, fmt)

    # order |a| >= |b|
    swap = (cb > ca) | ((cb == ca) & (fb > fa))
    sa_, sb_ = jnp.where(swap, sb, sa), jnp.where(swap, sa, sb)
    ca_, cb_ = jnp.where(swap, cb, ca), jnp.where(swap, ca, cb)
    fa_, fb_ = jnp.where(swap, fb, fa), jnp.where(swap, fa, fb)

    d = jnp.clip(ca_ - cb_, 0, _F + _G + 2)
    A = fa_ << _G
    Bs = fb_ << _G
    lost = Bs & ((_i64(1) << d) - 1)
    Bj = (Bs >> d) | (lost != 0).astype(_I64)          # jam sticky into bit 0
    eff_sub = sa_ != sb_
    mag = jnp.where(eff_sub, A - Bj, A + Bj)

    res_zero = mag == 0
    sig, sticky, _ = _normalize(mag, jnp.zeros_like(mag, dtype=bool))
    scale = ca_ + floor_log2(jnp.where(res_zero, 1, mag)) - (_F + _G)

    is_nar = na | nb
    is_zero = (za & zb) | (res_zero & ~is_nar)
    # exact-cancel sign: posit standard gives +0
    sign = jnp.where(za, sb_ & ~zb, jnp.where(zb, sa_, sa_))
    # if a is zero result is b, if b is zero result is a — fold via select:
    out = encode(sign, scale, sig, sticky, is_zero, is_nar, fmt, width=_F + _G)
    out = jnp.where(za & ~zb & ~is_nar, jnp.asarray(b, jnp.int32), out)
    out = jnp.where(zb & ~za & ~is_nar, jnp.asarray(a, jnp.int32), out)
    return out


def mul_(a, b, fmt: PositFormat = P32E2):
    za, na, sa, ca, fa = decode(a, fmt)
    zb, nb, sb, cb, fb = decode(b, fmt)
    sign = sa ^ sb
    scale = ca + cb
    prod = fa * fb                                      # < 2^56, exact
    ge2 = (prod >> (2 * _F + 1)) > 0
    scale = scale + ge2.astype(_I64)
    shift = (_F - _G) + ge2.astype(_I64)                # renormalize to F+G bits
    lost = prod & ((_i64(1) << shift) - 1)
    sig = prod >> shift
    sticky = lost != 0
    is_nar = na | nb
    is_zero = (za | zb) & ~is_nar
    return encode(sign, scale, sig, sticky, is_zero, is_nar, fmt, width=_F + _G)


def div_(a, b, fmt: PositFormat = P32E2):
    za, na, sa, ca, fa = decode(a, fmt)
    zb, nb, sb, cb, fb = decode(b, fmt)
    sign = sa ^ sb
    num = fa << (_F + _G + 1)                           # <= 2^59
    q = num // fb
    r = num - q * fb
    # q in (2^(F+G), 2^(F+G+2)): normalize to [2^(F+G), 2^(F+G+1)).
    # value = q * 2^(ca - cb - (F+G+1)), so scale = ca - cb - 1 (+1 if q >= 2).
    ge2 = (q >> (_F + _G + 1)) > 0
    scale = ca - cb - 1 + ge2.astype(_I64)
    lost = jnp.where(ge2, q & 1, 0)
    sig = jnp.where(ge2, q >> 1, q)
    sticky = (r != 0) | (lost != 0)
    is_nar = na | nb | zb                               # x/0 = NaR
    is_zero = za & ~is_nar
    return encode(sign, scale, sig, sticky, is_zero, is_nar, fmt, width=_F + _G)


def sqrt_(a, fmt: PositFormat = P32E2):
    za, na, sa, ca, fa = decode(a, fmt)
    is_nar = na | (sa & ~za)                            # sqrt(neg) = NaR
    half = ca >> 1                                      # floor(scale / 2)
    r = ca - (half << 1)                                # 0 or 1
    # a = fa * 2^(ca - F) = X * 2^(2*half - F - 33) with X = fa << (r + 33),
    # X in [2^60, 2^62) and F + 33 = 60 even => sqrt(a) = isqrt(X) * 2^(half-30)
    X = fa << (r + 33)
    s0 = jnp.floor(jnp.sqrt(X.astype(jnp.float64))).astype(_I64)
    # f64 estimate is within +-1 of the true integer sqrt; two correction
    # rounds make it exact.
    for _ in range(2):
        s0 = jnp.where((s0 + 1) * (s0 + 1) <= X, s0 + 1, s0)
        s0 = jnp.where(s0 * s0 > X, s0 - 1, s0)
    sticky = s0 * s0 != X
    # s0 in [2^30, 2^31) == [2^(F+G), 2^(F+G+1)) — already normalized.
    is_zero = za
    return encode(jnp.zeros_like(sa), half, s0, sticky, is_zero, is_nar, fmt,
                  width=_F + _G)


def neg_(a, fmt: PositFormat = P32E2):
    a = jnp.asarray(a, jnp.int32)
    return jnp.where(a == fmt.nar_pattern, a, -a)


def abs_(a, fmt: PositFormat = P32E2):
    a = jnp.asarray(a, jnp.int32)
    return jnp.where(a == fmt.nar_pattern, a, jnp.abs(a))


def is_nar(p, fmt: PositFormat = P32E2):
    """Elementwise NaR predicate on sign-extended posit words.

    NaR is the single pattern 10...0 (sign-extended: int32 -2^(nbits-1)
    for nbits=32, or its sign-extension for narrower formats), so the
    test is one word compare — no decode.  This is the check every NaR
    gate in the stack uses (``decode``, ``neg_``/``abs_``, the quire
    deposit); exposed so monitors (lapack.refine) and fault-tolerance
    verifiers (repro.ft) can ask "is this lane poisoned?" without
    reimplementing the pattern."""
    return jnp.asarray(p, jnp.int32) == fmt.nar_pattern


# --------------------------------------------------------------------------
# conversions (exact / correctly rounded)
# --------------------------------------------------------------------------

def to_float64(p, fmt: PositFormat = P32E2):
    is_zero, is_nar, sign, scale, sig = decode(p, fmt)
    mag = jnp.ldexp(sig.astype(jnp.float64), (scale - _F).astype(jnp.int32))
    out = jnp.where(sign, -mag, mag)
    out = jnp.where(is_zero, 0.0, out)
    out = jnp.where(is_nar, jnp.nan, out)
    return out


def from_float64(x, fmt: PositFormat = P32E2):
    x = jnp.asarray(x, jnp.float64)
    is_nar = jnp.isnan(x) | jnp.isinf(x)
    is_zero = (x == 0.0) & ~is_nar
    sign = x < 0
    # f64 subnormals (XLA frexp mishandles them) are far below every
    # format's minpos: clamp straight to minpos via the tiny flag.
    tiny = ~is_nar & ~is_zero & (jnp.abs(x) < np.float64(2.0 ** -1022))
    ax = jnp.abs(jnp.where(is_nar | is_zero | tiny, 1.0, x))
    mant, ex = jnp.frexp(ax)                            # mant in [0.5, 1)
    scale = ex.astype(_I64) - 1
    # One bit wider than the widest posit fraction (width F+1 = 28 > fs_max)
    # so encode's round position always sits strictly above sig's LSB —
    # with width == fs_max the round bit would be lost to truncation.
    R = mant * np.float64(1 << (_F + 2))                # in [2^{F+1}, 2^{F+2})
    sig = jnp.floor(R).astype(_I64)
    sticky = R != sig.astype(jnp.float64)
    scale = jnp.where(tiny, -(fmt.max_scale + 8), scale)
    return encode(sign, scale, sig, sticky, is_zero, is_nar, fmt, width=_F + 1)


def to_float32(p, fmt: PositFormat = P32E2):
    return to_float64(p, fmt).astype(jnp.float32)


def pconvert(p, src: PositFormat, dst: PositFormat):
    """Posit -> posit format conversion, correctly rounded (RNE on the
    destination pattern boundary).  Exact decode (every supported posit is
    f64-representable: <= 28-bit significands, |scale| <= 120) followed by
    one correctly-rounded encode, so widening (e.g. p16e1 -> p32e2) is
    exact and narrowing rounds once.  NaR maps to NaR, zero to zero.
    The mixed-precision IR solvers (lapack/refine.py rgesv_mp) perform
    this same decode-scale-encode dance with a power-of-two equilibration
    folded between the two halves — see refine.mp_narrow_matrix."""
    if src is dst:
        return jnp.asarray(p, jnp.int32)
    return from_float64(to_float64(p, src), dst)


def from_float32(x, fmt: PositFormat = P32E2):
    return from_float64(jnp.asarray(x, jnp.float32).astype(jnp.float64), fmt)


# --------------------------------------------------------------------------
# fast backend (f64 emulation) + public dispatch
# --------------------------------------------------------------------------

def _fast_binop(op):
    def f(a, b, fmt: PositFormat = P32E2):
        xa, xb = to_float64(a, fmt), to_float64(b, fmt)
        return from_float64(op(xa, xb), fmt)
    return f


_FAST = {
    "add": _fast_binop(jnp.add),
    "sub": _fast_binop(jnp.subtract),
    "mul": _fast_binop(jnp.multiply),
    "div": _fast_binop(jnp.divide),
    "sqrt": lambda a, fmt=P32E2: from_float64(jnp.sqrt(to_float64(a, fmt)), fmt),
}

_EXACT = {
    "add": add_,
    "sub": lambda a, b, fmt=P32E2: add_(a, neg_(b, fmt), fmt),
    "mul": mul_,
    "div": div_,
    "sqrt": sqrt_,
}


def _dispatch(name, backend):
    table = {"exact": _EXACT, "fast": _FAST}[backend]
    return table[name]


def add(a, b, fmt: PositFormat = P32E2, backend: str = "exact"):
    return _dispatch("add", backend)(a, b, fmt)


def sub(a, b, fmt: PositFormat = P32E2, backend: str = "exact"):
    return _dispatch("sub", backend)(a, b, fmt)


def mul(a, b, fmt: PositFormat = P32E2, backend: str = "exact"):
    return _dispatch("mul", backend)(a, b, fmt)


def div(a, b, fmt: PositFormat = P32E2, backend: str = "exact"):
    return _dispatch("div", backend)(a, b, fmt)


def sqrt(a, fmt: PositFormat = P32E2, backend: str = "exact"):
    return _dispatch("sqrt", backend)(a, fmt)


# --------------------------------------------------------------------------
# fused_chain helpers — decode-once / encode-once op chains
#
# The fast backend's binop decodes BOTH operands and encodes the result on
# EVERY call, so a chained update like  col - a*b  (the panel kernels'
# inner loop) decodes the same entries once per scalar op and round-trips
# the intermediate product through a posit word it immediately decodes
# again.  The chain form keeps values in f64 between ops and replaces the
# word round-trip with ``chain_round`` — round an f64 value to the posit
# lattice, staying in f64.  Because every posit value is exactly f64-
# representable (<= 28-bit significands, |scale| <= 120), a chain of
# {chain_round(op(...))} steps produces bit-for-bit the same values as the
# corresponding fast-backend word ops: decode once on entry
# (``chain_decode``), encode once on exit (``chain_encode``).
# --------------------------------------------------------------------------

def chain_decode(p, fmt: PositFormat = P32E2):
    """Posit words -> exact f64 values (decode once, at chain entry)."""
    return to_float64(p, fmt)


def chain_encode(x, fmt: PositFormat = P32E2):
    """f64 chain values -> posit words (encode once, at chain exit).
    Exact (no extra rounding) when x is already on the posit lattice,
    i.e. the output of a chain_* op."""
    return from_float64(x, fmt)


def chain_round(x, fmt: PositFormat = P32E2):
    """Round an f64 value to the nearest posit *value* (RNE on the pattern
    boundary, saturating, NaN -> NaN), staying in f64.  Bit-equivalent to
    ``to_float64(from_float64(x))`` (pinned by tests), but computed
    directly on (scale, significand) fields — no pattern pack/unpack, so
    a chain step costs roughly half an encode+decode round-trip.

    The rounding position is the posit pattern boundary: with
    ``reg_len``-bit regime the pattern keeps ``fs = nbits-1-reg_len-es``
    fraction bits, i.e. drops ``d = 29+es+reg_len-nbits`` low bits of the
    30-bit ``[e|frac]`` field (28 fraction bits + hidden bit above).  Ties
    go to the even *pattern*: the pattern LSB is an ``[e|frac]`` bit while
    ``d < es+28``, but degenerates to the regime terminator (0 for k >= 0,
    1 for k < 0) when the whole ``[e|frac]`` field is dropped — the
    near-maxpos/minpos fringe where value-space "even" and pattern-space
    "even" disagree.
    """
    x = jnp.asarray(x, jnp.float64)
    nbits, es = fmt.nbits, fmt.es
    is_nan = jnp.isnan(x) | jnp.isinf(x)
    is_zero = (x == 0.0) & ~is_nan
    sign = x < 0
    # f64 subnormals sit far below every format's minpos: clamp via `tiny`
    # (same rule as from_float64).
    tiny = ~is_nan & ~is_zero & (jnp.abs(x) < np.float64(2.0 ** -1022))
    ax = jnp.abs(jnp.where(is_nan | is_zero | tiny, 1.0, x))
    mant, ex = jnp.frexp(ax)                            # mant in [0.5, 1)
    scale = ex.astype(_I64) - 1
    R = mant * np.float64(1 << 29)                      # [2^28, 2^29)
    q = jnp.floor(R)
    sticky = R != q
    frac = q.astype(_I64) & ((_i64(1) << 28) - 1)

    k = scale >> es
    e = scale - (k << es)
    reg_len = jnp.where(k >= 0, k + 2, 1 - k)
    ef = (_i64(1) << (es + 28)) | (e << 28) | frac      # [1|e|frac28]
    d = jnp.clip(29 + es + reg_len - nbits, 1, es + 28)
    dropped = ef & ((_i64(1) << d) - 1)
    half = _i64(1) << (d - 1)
    kept = ef >> d
    pat_lsb = jnp.where(d == es + 28,
                        jnp.where(k < 0, _i64(1), _i64(0)), kept & 1)
    rnd = (dropped > half) | ((dropped == half) & (sticky | (pat_lsb == 1)))

    q2 = (kept + rnd.astype(_I64)) << d                 # back at [1|e|frac]
    carry = q2 >> (es + 29)                             # regime carry: 2^(es(k+1))
    k2 = k + carry
    e2 = jnp.where(carry == 1, 0, (q2 >> 28) & ((_i64(1) << es) - 1))
    frac2 = jnp.where(carry == 1, 0, q2 & ((_i64(1) << 28) - 1))
    scale2 = (k2 << es) + e2
    mag = jnp.ldexp((frac2 + (_i64(1) << 28)).astype(jnp.float64),
                    (scale2 - 28).astype(jnp.int32))

    # saturation: every value with scale >= max_scale rounds to maxpos
    # (the k = k_max regime has no e/frac room), mirroring encode's
    # over-clamp + pattern minimum; under mirrors the minpos clamp.
    over = scale >= fmt.max_scale
    under = (scale < -fmt.max_scale) | tiny
    mag = jnp.where(over, np.float64(2.0) ** fmt.max_scale, mag)
    mag = jnp.where(under, np.float64(2.0) ** (-fmt.max_scale), mag)
    out = jnp.where(sign, -mag, mag)
    out = jnp.where(is_zero, 0.0, out)
    return jnp.where(is_nan, jnp.float64(jnp.nan), out)


def chain_add(a, b, fmt: PositFormat = P32E2):
    return chain_round(a + b, fmt)


def chain_sub(a, b, fmt: PositFormat = P32E2):
    return chain_round(a - b, fmt)


def chain_mul(a, b, fmt: PositFormat = P32E2):
    return chain_round(a * b, fmt)


def chain_div(a, b, fmt: PositFormat = P32E2):
    return chain_round(a / b, fmt)


def chain_sqrt(a, fmt: PositFormat = P32E2):
    return chain_round(jnp.sqrt(a), fmt)


# --------------------------------------------------------------------------
# epsilon model (paper §2: golden zone)
# --------------------------------------------------------------------------

def rounding_eps(x, fmt: PositFormat = P32E2):
    """Relative rounding ulp of |x| in this format (the paper's epsilon_posit,
    which beats binary32's 6e-8 only inside the golden zone)."""
    x = jnp.abs(jnp.asarray(x, jnp.float64))
    safe = jnp.where(x == 0, 1.0, x)
    _, ex = jnp.frexp(safe)
    scale = ex - 1
    k = scale >> fmt.es
    reg_len = jnp.where(k >= 0, k + 2, 1 - k)
    fs = jnp.clip(fmt.nbits - 1 - reg_len - fmt.es, 0, None)
    return jnp.where(x == 0, 0.0, 2.0 ** (-fs.astype(jnp.float64)))


def from_float32_bits(x, fmt: PositFormat = P32E2):
    """f32 -> posit via int32 bit extraction — no f64 anywhere, so this is
    the TPU-legal path (used by the posit16 optimizer/collective codecs and
    the QAT quantizer).  Correctly rounds the f32 value to the posit
    lattice (f32 carries 24 significand bits; encode's round position needs
    width > fs_max, satisfied for every supported format)."""
    x = jnp.asarray(x, jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    sign = bits < 0
    exp_f = (bits >> 23) & 0xFF
    man = bits & 0x7FFFFF
    is_zero = (exp_f == 0) & (man == 0)
    is_nar = exp_f == 255
    # subnormals (< 2^-126) are far below every supported format's minpos:
    # give them an under-range scale so encode clamps to minpos.
    scale = jnp.where(exp_f == 0, -150, exp_f.astype(jnp.int32) - 127)
    # zero-pad the 24-bit f32 significand to width F+1: encode requires the
    # round position strictly above the significand LSB (width > fs_max).
    sig = (((jnp.int32(1) << 23) | man).astype(_I64)) << (_F + 1 - 23)
    return encode(sign, _i64(scale), sig, False, is_zero, is_nar, fmt,
                  width=_F + 1)


def to_float32_bits(p, fmt: PositFormat = P32E2):
    """posit -> f32 without f64: exact for <= 24-bit significands (all of
    p16e1/p8e0; p32e2 rounds RNE to f32 via the astype)."""
    is_zero, is_nar, sign, scale, sig = decode(p, fmt)
    mag = jnp.ldexp(sig.astype(jnp.float32), (scale - _F).astype(jnp.int32))
    out = jnp.where(sign, -mag, mag)
    out = jnp.where(is_zero, jnp.float32(0.0), out)
    return jnp.where(is_nar, jnp.float32(jnp.nan), out)


@functools.lru_cache(maxsize=None)
def jitted(name: str, fmt_name: str = "p32e2", backend: str = "exact"):
    """jit-compiled op handle, cached per (op, format, backend)."""
    fmt = get_format(fmt_name)
    fn = {"add": add, "sub": sub, "mul": mul, "div": div}.get(name)
    if fn is not None:
        return jax.jit(lambda a, b: fn(a, b, fmt, backend))
    if name == "sqrt":
        return jax.jit(lambda a: sqrt(a, fmt, backend))
    if name == "to_f64":
        return jax.jit(lambda a: to_float64(a, fmt))
    if name == "from_f64":
        return jax.jit(lambda x: from_float64(x, fmt))
    raise KeyError(name)
