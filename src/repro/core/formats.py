"""Posit format descriptors.

A ``PositFormat`` pins down Posit(nbits, es) per the posit standard (2022)
and Gustafson & Yonemoto 2017 [11]:

    x = (-1)^s * u^k * 2^e * 1.f,   u = 2^(2^es)

Patterns are stored **sign-extended in int32** (int arithmetic negation of a
pattern is the posit negation, which keeps all ops branch-free).

Only the formats used by the paper + the framework are registered:
  * p32e2 — the paper's Posit(32,2), the working format of the LAPACK stack
  * p16e1 — half-width: the mixed-precision factorization format
            (lapack/refine.py rgesv_mp) and gradient / optimizer-state
            compression
  * p8e2  — narrow + wide dynamic range (es=2 stretches maxpos to 2^24);
            the Fixed-Posit-style accuracy/throughput trade point
  * p8e0  — beyond-paper: extreme compression experiments

Every registered format shares ONE field-space implementation in
core/posit.py (decode/encode/chain_round are parametric in (nbits, es)
and pinned bit-exact against the rational oracle per format in
tests/test_formats.py); the derived constants below are the only place
format-specific numbers live.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PositFormat:
    nbits: int
    es: int

    # ---- derived constants -------------------------------------------------
    @property
    def name(self) -> str:
        return f"p{self.nbits}e{self.es}"

    @property
    def useed(self) -> int:
        return 1 << (1 << self.es)

    @property
    def max_k(self) -> int:
        return self.nbits - 2

    @property
    def max_scale(self) -> int:
        """Scale (power of two) of maxpos: (nbits-2) * 2^es."""
        return self.max_k << self.es

    @property
    def maxpos_pattern(self) -> int:
        return (1 << (self.nbits - 1)) - 1

    @property
    def minpos_pattern(self) -> int:
        return 1

    @property
    def nar_pattern(self) -> int:
        """NaR sign-extended into int32 (e.g. p32: -2^31, p16: -2^15)."""
        return -(1 << (self.nbits - 1))

    @property
    def max_frac_bits(self) -> int:
        """fs for the shortest regime (|k| minimal): nbits - 3 - es."""
        return self.nbits - 3 - self.es

    @property
    def maxpos(self) -> float:
        return float(2.0 ** self.max_scale)

    @property
    def minpos(self) -> float:
        return float(2.0 ** (-self.max_scale))

    @property
    def eps_at_1(self) -> float:
        """Rounding ulp at x=1 (the paper's golden-zone machine epsilon)."""
        return float(2.0 ** (-self.max_frac_bits))

    @property
    def storage_dtype(self):
        return np.int32

    @property
    def wire_dtype(self):
        """Narrowest integer dtype that round-trips the pattern on the wire
        (used by posit-compressed collectives)."""
        if self.nbits <= 8:
            return np.int8
        if self.nbits <= 16:
            return np.int16
        return np.int32


P32E2 = PositFormat(32, 2)
P16E1 = PositFormat(16, 1)
P8E2 = PositFormat(8, 2)
P8E0 = PositFormat(8, 0)

FORMATS: dict[str, PositFormat] = {
    f.name: f for f in (P32E2, P16E1, P8E2, P8E0)}


def get_format(name: str) -> PositFormat:
    try:
        return FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown posit format {name!r}; known: {sorted(FORMATS)}")
