"""Core: Posit(n, es) arithmetic (the paper's contribution) + format policy."""
from repro.core.formats import FORMATS, P8E0, P16E1, P32E2, PositFormat, get_format
from repro.core import posit
from repro.core.policy import (Policy, decode_tensor, encode_tensor,
                               get_policy, quantize)

__all__ = [
    "FORMATS", "P8E0", "P16E1", "P32E2", "PositFormat", "get_format",
    "posit", "Policy", "decode_tensor", "encode_tensor", "get_policy",
    "quantize",
]
