"""Numeric-format policy: posit as a first-class dtype in the framework.

The paper's thesis is that *format choice x data magnitude* determines both
accuracy and cost.  This module makes that a framework-level knob:

* ``quantize``/``dequantize`` — straight-through posit quantization of f32
  tensors (custom_vjp identity gradient), used by ``PositLinear`` for
  weights/activations.  Simulated-quantization semantics: values are rounded
  to the exact posit lattice, compute proceeds in f32/bf16 — this is the
  standard QAT contract and is what the Pallas kernel reproduces natively.
* ``encode_tensor``/``decode_tensor`` — bit-pattern (de)serialization used by
  the checkpoint codec and the posit-compressed collectives
  (``repro.launch.collectives``).
* ``Policy`` — per-subsystem format selection resolved from arch configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import FORMATS, PositFormat, get_format


# --------------------------------------------------------------------------
# straight-through quantization
# --------------------------------------------------------------------------

import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _st_quantize(x: jax.Array, fmt_id: int) -> jax.Array:
    return _quantize_impl(x, fmt_id)


def _quantize_impl(x, fmt_id):
    fmt = _FMT_BY_ID[fmt_id]
    orig_dtype = x.dtype
    p = posit.from_float32_bits(x.astype(jnp.float32), fmt)
    return posit.to_float32_bits(p, fmt).astype(orig_dtype)


def _st_fwd(x, fmt_id):
    return _quantize_impl(x, fmt_id), None


def _st_bwd(fmt_id, _, g):
    return (g,)


_st_quantize.defvjp(_st_fwd, _st_bwd)

_FMT_IDS = {name: i for i, name in enumerate(sorted(FORMATS))}
_FMT_BY_ID = {i: FORMATS[name] for name, i in _FMT_IDS.items()}


def quantize(x: jax.Array, fmt: str | PositFormat = "p32e2") -> jax.Array:
    """Round ``x`` to the posit lattice of ``fmt`` (straight-through grad)."""
    if isinstance(fmt, PositFormat):
        fmt = fmt.name
    return _st_quantize(x, _FMT_IDS[fmt])


# --------------------------------------------------------------------------
# wire codecs (for checkpoints and compressed collectives)
# --------------------------------------------------------------------------

def encode_tensor(x: jax.Array, fmt: str | PositFormat = "p16e1") -> jax.Array:
    """float tensor -> posit bit patterns in the narrowest wire dtype
    (f32-native codec: runs on TPU, no f64)."""
    f = get_format(fmt) if isinstance(fmt, str) else fmt
    p = posit.from_float32_bits(jnp.asarray(x, jnp.float32), f)
    return p.astype(f.wire_dtype)


def decode_tensor(p: jax.Array, fmt: str | PositFormat = "p16e1",
                  dtype=jnp.float32) -> jax.Array:
    f = get_format(fmt) if isinstance(fmt, str) else fmt
    return posit.to_float32_bits(p.astype(jnp.int32), f).astype(dtype)


# --------------------------------------------------------------------------
# policy
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Policy:
    """Where posit formats are applied in the training/serving stack.

    ``gemm``: 'bf16' (baseline), 'posit32' (paper-faithful simulated GEMM via
    PositLinear quantization), or 'posit32_split' (beyond-paper: hi/lo-split
    MXU path, see kernels/posit_gemm.py).
    ``weights``/``activations``: quantization lattice applied in PositLinear.
    ``grad_compression``: wire format for cross-device gradient reduction
    (None disables; 'p16e1' halves collective bytes vs f32).
    ``master_dtype``: optimizer master-weight dtype.
    """
    gemm: str = "bf16"
    weights: Optional[str] = None
    activations: Optional[str] = None
    grad_compression: Optional[str] = None
    opt_compression: Optional[str] = None   # posit16 optimizer moments
    master_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def maybe_quantize_weights(self, w: jax.Array) -> jax.Array:
        return quantize(w, self.weights) if self.weights else w

    def maybe_quantize_acts(self, x: jax.Array) -> jax.Array:
        return quantize(x, self.activations) if self.activations else x


BF16_BASELINE = Policy()
PAPER_POSIT32 = Policy(gemm="posit32", weights="p32e2", activations="p32e2",
                       compute_dtype="float32")
POSIT_SPLIT = Policy(gemm="posit32_split", weights="p32e2",
                     activations="p32e2", compute_dtype="float32")
POSIT_COMPRESSED_DP = Policy(grad_compression="p16e1")
POSIT_OPT16 = Policy(opt_compression="p16e1")

F32_SERVE = Policy(compute_dtype="float32")

POLICIES = {
    "bf16": BF16_BASELINE,
    "f32": F32_SERVE,
    "posit32": PAPER_POSIT32,
    "posit32_split": POSIT_SPLIT,
    "posit_dp": POSIT_COMPRESSED_DP,
    "bf16_opt16": POSIT_OPT16,
}


def get_policy(name: str) -> Policy:
    return POLICIES[name]
