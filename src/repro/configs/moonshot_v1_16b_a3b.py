"""moonshot-v1-16b-a3b (Moonlight) [hf:moonshotai/Moonlight-16B-A3B]."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_head=128, d_ff=1408, vocab=163840,
    act="silu", n_experts=64, top_k=6, rope_theta=50000.0,
    tie_embeddings=True)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=96, vocab=256, n_experts=8, top_k=2)
