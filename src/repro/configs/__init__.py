"""Architecture registry: one module per assigned architecture.

``get_config(name)`` accepts the dashed public id (e.g. 'qwen2-0.5b').
Every module exposes ``config()`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from repro.configs.shapes import (SHAPE_CELLS, ShapeCell, applicable_cells,
                                  cell_by_name, tiny_config)

ARCH_IDS = [
    "whisper-tiny",
    "moonshot-v1-16b-a3b",
    "granite-moe-1b-a400m",
    "zamba2-2.7b",
    "qwen2-0.5b",
    "llama3-405b",
    "gemma3-12b",
    "starcoder2-7b",
    "mamba2-780m",
    "internvl2-26b",
]


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, policy: str | None = None):
    cfg = _module(name).config()
    if policy is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, policy=policy)
    return cfg


def get_smoke_config(name: str):
    return _module(name).smoke_config()


def get_tiny_config(name: str, policy: str | None = None):
    cfg = tiny_config(name)
    if policy is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, policy=policy)
    return cfg


__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "get_tiny_config",
           "SHAPE_CELLS", "ShapeCell", "applicable_cells", "cell_by_name",
           "tiny_config"]
