"""internvl2-26b [arXiv:2404.16821]: InternViT (stub) + InternLM2-20B."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_head=128, d_ff=16384, vocab=92553,
    act="silu", rope_theta=1000000.0, vis_tokens=1024,
    tie_embeddings=False, policy="bf16_opt16")


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256, vis_tokens=8)
