"""The assigned input-shape set (same four cells for every LM arch).

``train_*``  lowers train_step (fwd+bwd+optimizer);
``prefill_*`` lowers prefill_step (forward logits over the full prompt);
``decode_*``/``long_*`` lower serve_step (one new token against a KV/state
cache of the given sequence length).

long_500k requires a sub-quadratic stack (ssm / hybrid / local-windowed);
pure full-attention archs skip it (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

from repro.models.common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    needs_sub_quadratic: bool = False


SHAPE_CELLS = [
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1, needs_sub_quadratic=True),
]


def cell_by_name(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)


def applicable_cells(cfg: ArchConfig) -> list[ShapeCell]:
    out = []
    for c in SHAPE_CELLS:
        if c.needs_sub_quadratic and not cfg.sub_quadratic:
            continue
        out.append(c)
    return out


def tiny_config(name: str) -> ArchConfig:
    """Test-scale variant of an arch: the family's smoke config shrunk
    further (tiny vocab / FFN / modality stubs) so serving and
    quantization tests — which run many decode steps and several
    quantized formats per case — finish in seconds.  Keeps the layer
    count, period structure and head layout of the smoke config, so
    the scan/caching topology under test is unchanged."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config(name)
    repl: dict = {"name": cfg.name.replace("smoke", "tiny"),
                  "vocab": min(cfg.vocab, 128)}
    if cfg.d_ff:
        repl["d_ff"] = min(cfg.d_ff, 96)
    if cfg.enc_seq:
        repl["enc_seq"] = min(cfg.enc_seq, 16)
    if cfg.vis_tokens:
        repl["vis_tokens"] = min(cfg.vis_tokens, 4)
    return dataclasses.replace(cfg, **repl)
