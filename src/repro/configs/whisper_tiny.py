"""whisper-tiny [arXiv:2212.04356]: enc-dec, conv frontend stubbed."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="whisper-tiny", family="encdec", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_head=64, d_ff=1536, vocab=51865,
    act="gelu", qkv_bias=True, enc_layers=4, enc_seq=1500,
    tie_embeddings=True, norm_eps=1e-5)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="whisper-tiny-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=128, enc_layers=2,
        enc_seq=16)
