"""qwen2-0.5b [arXiv:2407.10671]: dense GQA with QKV bias."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_head=64, d_ff=4864, vocab=151936,
    act="silu", qkv_bias=True, rope_theta=1000000.0, tie_embeddings=True)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="qwen2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256)
