"""mamba2-780m [arXiv:2405.21060]: pure SSD stack, attention-free."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=1, n_kv_heads=1, d_head=64, d_ff=0, vocab=50280,
    act="silu", ssm_state=128, ssm_expand=2, ssm_conv=4, ssm_head_dim=64,
    tie_embeddings=True)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
        ssm_state=16, ssm_head_dim=16)
