"""gemma3-12b [hf:google/gemma-3-*-pt]: 5:1 local:global, 256k vocab."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
    n_heads=16, n_kv_heads=8, d_head=256, d_ff=15360, vocab=262144,
    act="gelu", local_window=1024, local_ratio=5, rope_theta=1000000.0,
    tie_embeddings=True)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="gemma3-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256, local_window=8,
        local_ratio=2)
