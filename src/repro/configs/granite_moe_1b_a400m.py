"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, d_head=64, d_ff=512, vocab=49155,
    act="silu", n_experts=32, top_k=8, tie_embeddings=True)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=64, vocab=256, n_experts=4, top_k=2)
