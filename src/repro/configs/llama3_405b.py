"""llama3-405b [arXiv:2407.21783]: dense GQA, 128k vocab."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_head=128, d_ff=53248, vocab=128256,
    act="silu", rope_theta=500000.0, tie_embeddings=False, policy="bf16_opt16")


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="llama3-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=16, d_ff=192, vocab=256)
