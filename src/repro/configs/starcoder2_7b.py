"""starcoder2-7b [arXiv:2402.19173]: dense GQA with bias, GELU."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, d_head=128, d_ff=18432, vocab=49152,
    act="gelu", qkv_bias=True, rope_theta=100000.0, tie_embeddings=True)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256)
