"""zamba2-2.7b [arXiv:2411.15242]: Mamba2 stack + weight-shared attn block."""
import dataclasses
from repro.models.common import ArchConfig

_BASE = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_head=80, d_ff=10240, vocab=32000,
    act="silu", ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_head_dim=64,
    hybrid_attn_every=6, tie_embeddings=True)


def config():
    return _BASE


def smoke_config():
    return dataclasses.replace(
        _BASE, name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=256, ssm_state=16,
        ssm_head_dim=16, hybrid_attn_every=2)
