"""AdamW with optional posit16 state compression.

Beyond-paper application of the paper's golden-zone insight (§5.1: "scaling
... by a factor that makes the absolute values ... as close to 1 as possible
is effective"): optimizer moments are stored as Posit(16,1) words after a
static re-centering scale that moves their typical magnitude into the posit
golden zone, where p16e1 carries 12 fraction bits (vs bf16's 7).  This
halves optimizer-state bytes vs f32 (m: 2B, v: 2B) — the difference between
llama3-405b + AdamW fitting a single v5e-256 pod or not (EXPERIMENTS.md).

States: m, v (compressed or f32), step counter.  Update math runs in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.policy import decode_tensor, encode_tensor

# Golden-zone re-centering scales: chosen so typical |m| ~ 1e-3*lr-grad
# and |v| ~ grad^2 land near 1.0 when multiplied.
_M_SCALE = 2.0 ** 10
_V_SCALE = 2.0 ** 24


def _compress(x, scale):
    return encode_tensor(x.astype(jnp.float32) * jnp.float32(scale), "p16e1")


def _decompress(p, scale):
    return decode_tensor(p, "p16e1") * jnp.float32(1.0 / scale)


def _moment_like(w, compress: bool):
    z = jnp.zeros(w.shape, jnp.float32)
    return _compress(z, 1.0) if compress else z


def adamw_init(params, compress_moments: bool = False):
    def init_leaf(w):
        return {"m": _moment_like(w, compress_moments),
                "v": _moment_like(w, compress_moments)}
    moments = jax.tree.map(init_leaf, params)
    return {"moments": moments, "step": jnp.zeros((), jnp.int32),
            }


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps", "wd",
                                             "clip", "compress_moments"),
                   donate_argnums=(0, 1))
def adamw_update(params, opt_state, grads, *, lr=3e-4, b1=0.9, b2=0.95,
                 eps=1e-8, wd=0.01, clip=1.0, compress_moments=False):
    """One AdamW step.  params/grads: matching pytrees of f32 leaves."""
    step = opt_state["step"] + 1
    tstep = step.astype(jnp.float32)

    # global-norm clip
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in leaves))
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))

    c1 = 1.0 - b1 ** tstep
    c2 = 1.0 - b2 ** tstep

    def upd(w, g, mo):
        g = g.astype(jnp.float32) * scale
        m = _decompress(mo["m"], _M_SCALE) if compress_moments else mo["m"]
        v = _decompress(mo["v"], _V_SCALE) if compress_moments else mo["v"]
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        new_w = (w.astype(jnp.float32)
                 - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w.astype(
                     jnp.float32)))
        if compress_moments:
            mo = {"m": _compress(m, _M_SCALE), "v": _compress(v, _V_SCALE)}
        else:
            mo = {"m": m, "v": v}
        return new_w.astype(w.dtype), mo

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mo = treedef.flatten_up_to(opt_state["moments"])
    out = [upd(w, g, mo) for w, g, mo in zip(flat_p, flat_g, flat_mo)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_moments = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, {"moments": new_moments, "step": step}, gnorm
