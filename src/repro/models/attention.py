"""Blockwise (flash-style) attention: GQA, causal, sliding-window, cross,
and ring-buffer KV-cache decode — pure jnp, O(S * chunk) memory, shardable.

The kv-chunk scan keeps running (max, sum, acc) statistics so the S x S
score matrix is never materialized; this is what lets the 32k-prefill and
500k-decode dry-run cells fit HBM (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, linear, linear_init, rope

_NEG = jnp.float32(-1e30)


def attn_init(key, cfg: ArchConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_init(ks[0], cfg.d_model, cfg.d_q, (None, "heads"),
                          bias=cfg.qkv_bias),
        "wk": linear_init(ks[1], cfg.d_model, cfg.d_kv, (None, "heads"),
                          bias=cfg.qkv_bias),
        "wv": linear_init(ks[2], cfg.d_model, cfg.d_kv, (None, "heads"),
                          bias=cfg.qkv_bias),
        "wo": linear_init(ks[3], cfg.d_q, cfg.d_model, ("heads", None)),
    }


def blockwise_attention(q, k, v, *, q_positions, causal: bool,
                        window: int = 0, kv_valid_len=None,
                        kv_positions=None, chunk: int = 512):
    """q: (B,Sq,Hq,Dh); k,v: (B,Sk,Hkv,Dh).  Returns (B,Sq,Hq,Dh).

    ``q_positions``: (Sq,) absolute positions of the queries.
    ``kv_positions``: (Sk,) absolute positions of cache slots (defaults to
    0..Sk-1; ring-buffer caches pass their slot->position map).
    ``kv_valid_len``: scalar — slots at positions >= this are masked out.
    """
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    # q stays in compute dtype (an f32 q-shaped tensor would be stacked as
    # an f32 residual by the layer scan); scores accumulate in f32.
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = jnp.float32(1.0 / dh ** 0.5)
    if kv_positions is None:
        kv_positions = jnp.arange(sk, dtype=jnp.int32)

    if sq == 1:
        # decode: scores are (B,1,Hkv,G,Sk) — small even at 500k context.
        # Single-shot softmax; no chunk scan (the chunked reshape also
        # trips an XLA GSPMD CHECK on dp-less decode meshes).
        # Per-batch generalization (serving engine): q_positions may be
        # (B,1), kv_positions (B,Sk) and kv_valid_len (B,) — the mask
        # becomes (B,Sk).  The scalar path builds the SAME mask values
        # broadcast from (1,Sk), so single-request decode is unchanged.
        qpos = q_positions.astype(jnp.int32)
        if qpos.ndim == 1:
            qpos = qpos[None, :]                         # (1,1)
        kvp = kv_positions if kv_positions.ndim == 2 \
            else kv_positions[None, :]                   # (B|1,Sk)
        s = jnp.einsum("bshgd,bchd->bshgc", qg, k.astype(qg.dtype),
                       preferred_element_type=jnp.float32) * scale
        mask = kvp >= 0
        if causal:
            mask &= qpos[:, :1] >= kvp
        if window:
            mask &= qpos[:, :1] - kvp < window
        if kv_valid_len is not None:
            vlen = jnp.asarray(kv_valid_len, jnp.int32).reshape(-1, 1)
            mask &= kvp < vlen
        s = jnp.where(mask[:, None, None, None, :], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bshgc,bchd->bshgd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, sq, hq, dh).astype(q.dtype)

    # Align chunks with the sequence sharding: a chunk must never straddle
    # a 'model'-axis shard of S, or SPMD loses the sharding through the
    # (S -> chunks) reshape and replicates the whole attention (observed:
    # 128 GiB residuals on llama3-405b train_4k — EXPERIMENTS.md §Perf).
    from repro.launch import context as dist_ctx
    ctx = dist_ctx.current()
    n_shards = ctx.mesh.shape.get("model", 1) if ctx is not None else 1
    shard_size = sk // n_shards if sk % n_shards == 0 else sk
    chunk = min(chunk, shard_size, sk)
    if shard_size % chunk:               # largest divisor of shard_size
        chunk = next(c for c in range(chunk, 0, -1) if shard_size % c == 0)
    n_chunks = sk // chunk
    kc = k.reshape(b, n_chunks, chunk, hkv, dh)
    vc = v.reshape(b, n_chunks, chunk, hkv, dh)
    pc = kv_positions.reshape(n_chunks, chunk)

    qpos = q_positions.astype(jnp.int32)
    vlen = jnp.int32(kv_valid_len) if kv_valid_len is not None \
        else jnp.int32(2 ** 30)
    flash = _make_flash(causal, window)
    out = flash(qg, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc,
                qpos, vlen)
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


# --------------------------------------------------------------------------
# flash attention with a hand-written VJP
#
# jax.checkpoint-of-scan-step still stacks the (m, l, acc) carries per chunk
# for the backward pass (observed as the 2-4 GiB f32 residual stacks on
# llama3-405b train — EXPERIMENTS.md §Perf-1 iter 6).  A custom VJP removes
# ALL per-chunk residuals: the forward saves only (q, k, v, out, lse), the
# backward rescans chunks recomputing p on the fly (standard FlashAttention
# backward).
# --------------------------------------------------------------------------

def _chunk_mask(qpos, pj, vlen, causal, window):
    mask = (pj[None, :] >= 0) & (pj[None, :] < vlen)
    if causal:
        mask = mask & (qpos[:, None] >= pj[None, :])
    if window:
        mask = mask & (qpos[:, None] - pj[None, :] < window)
    return mask                                          # (Sq, C)


def _flash_fwd_scan(qg, kc, vc, pc, qpos, vlen, causal, window):
    b, sq, hkv, g, dh = qg.shape
    scale = jnp.float32(1.0 / dh ** 0.5)

    def step(carry, inp):
        m, l, acc = carry
        kj, vj, pj = inp
        s = jnp.einsum("bshgd,bchd->bshgc", qg, kj.astype(qg.dtype),
                       preferred_element_type=jnp.float32) * scale
        mask = _chunk_mask(qpos, pj, vlen, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        upd = jnp.einsum("bshgc,bchd->bshgd", p.astype(vj.dtype),
                         vj, preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + upd
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, g), _NEG)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window: int):
    @jax.custom_vjp
    def flash(qg, kc, vc, pc, qpos, vlen):
        out, _ = _flash_fwd_scan(qg, kc, vc, pc, qpos, vlen, causal, window)
        return out

    def fwd(qg, kc, vc, pc, qpos, vlen):
        out, lse = _flash_fwd_scan(qg, kc, vc, pc, qpos, vlen, causal,
                                   window)
        return out, (qg, kc, vc, pc, qpos, vlen, out, lse)

    def bwd(res, dout):
        qg, kc, vc, pc, qpos, vlen, out, lse = res
        dh = qg.shape[-1]
        scale = jnp.float32(1.0 / dh ** 0.5)
        dout = dout.astype(jnp.float32)
        delta = jnp.sum(dout * out, axis=-1)             # (B,Sq,Hkv,G)
        dt = qg.dtype

        def step(dq, inp):
            kj, vj, pj = inp
            s = jnp.einsum("bshgd,bchd->bshgc", qg, kj.astype(dt),
                           preferred_element_type=jnp.float32) * scale
            mask = _chunk_mask(qpos, pj, vlen, causal, window)
            p = jnp.where(mask[None, :, None, None, :],
                          jnp.exp(s - lse[..., None]), 0.0)
            pb = p.astype(dt)
            dvj = jnp.einsum("bshgc,bshgd->bchd", pb, dout.astype(dt),
                             preferred_element_type=jnp.float32)
            dp = jnp.einsum("bshgd,bchd->bshgc", dout.astype(dt), vj,
                            preferred_element_type=jnp.float32)
            ds = (p * (dp - delta[..., None]) * scale).astype(dt)
            dq = dq + jnp.einsum("bshgc,bchd->bshgd", ds, kj,
                                 preferred_element_type=jnp.float32)
            dkj = jnp.einsum("bshgc,bshgd->bchd", ds, qg,
                             preferred_element_type=jnp.float32)
            return dq, (dkj.astype(kc.dtype), dvj.astype(vc.dtype))

        dq0 = jnp.zeros(qg.shape, jnp.float32)
        dq, (dkc, dvc) = jax.lax.scan(step, dq0, (kc, vc, pc))
        return (dq.astype(qg.dtype), dkc, dvc, None, None, None)

    flash.defvjp(fwd, bwd)
    return flash


def attn_apply(params, x, cfg: ArchConfig, policy, compute_dtype, *,
               positions, causal=True, window=0, kv_cache=None,
               cache_pos=None, cross_kv=None):
    """Self/cross attention with optional KV cache.

    Train/prefill: kv_cache None, full-sequence.
    Decode: kv_cache {'k','v'} (B, Scache, Hkv, Dh); cache_pos scalar =
    absolute position of the incoming token(s); returns updated cache.
    Cross: cross_kv = (k, v) precomputed from the encoder.
    """
    b, s, _ = x.shape
    q = linear(params["wq"], x, policy, compute_dtype)
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    if cross_kv is None:
        k = linear(params["wk"], x, policy, compute_dtype)
        v = linear(params["wv"], x, policy, compute_dtype)
        k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv

    new_cache = None
    if kv_cache is not None:
        s_cache = kv_cache["k"].shape[1]
        cp = jnp.asarray(cache_pos, jnp.int32)
        idx = jnp.arange(s_cache, dtype=jnp.int32)
        if cp.ndim == 0:
            slot = (cp % s_cache).astype(jnp.int32)
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), slot, axis=1)
            # slot i holds absolute position p = pos - ((pos - i) mod Sc)
            kv_pos = cp - ((cp - idx) % s_cache)
            vlen = cp + 1
        else:
            # per-batch positions (serving engine, (B,)): one-hot
            # where-scatter into each row's own slot, per-row slot->pos
            # map and valid length
            slot = cp % s_cache                          # (B,)
            hit = (idx[None, :] == slot[:, None])        # (B,Sc)
            ck = jnp.where(hit[:, :, None, None],
                           k.astype(kv_cache["k"].dtype), kv_cache["k"])
            cv = jnp.where(hit[:, :, None, None],
                           v.astype(kv_cache["v"].dtype), kv_cache["v"])
            kv_pos = cp[:, None] - ((cp[:, None] - idx[None, :]) % s_cache)
            vlen = cp + 1                                # (B,)
        new_cache = {"k": ck, "v": cv}
        out = blockwise_attention(
            q, ck, cv, q_positions=positions, causal=causal,
            window=window, kv_valid_len=vlen, kv_positions=kv_pos)
    else:
        out = blockwise_attention(q, k, v, q_positions=positions,
                                  causal=causal, window=window)

    out = out.reshape(b, s, cfg.d_q)
    y = linear(params["wo"], out, policy, compute_dtype)
    return y, new_cache


def cross_kv_init(params, enc_out, cfg: ArchConfig, policy, compute_dtype):
    """Precompute encoder K/V for decoder cross-attention."""
    b, se, _ = enc_out.shape
    k = linear(params["wk"], enc_out, policy, compute_dtype)
    v = linear(params["wv"], enc_out, policy, compute_dtype)
    return (k.reshape(b, se, cfg.n_kv_heads, cfg.d_head),
            v.reshape(b, se, cfg.n_kv_heads, cfg.d_head))
