"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: within a chunk the recurrence is computed in its
"attention dual" form (C B^T with decay mask — quadratic in chunk length),
across chunks a linear state recurrence is scanned.  Memory is
O(S*chunk + S/chunk * state) — this is what makes the long_500k shapes
feasible for the ssm/hybrid architectures.

Decode carries a tiny recurrent cache: conv tail (k-1 steps) + SSM state
(B, H, P, N) — constant in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (ArchConfig, leaf, linear, linear_init,
                                 param, rmsnorm, rmsnorm_init)

_CHUNK = 256


def ssm_init(key, cfg: ArchConfig):
    d, din, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n
    ks = jax.random.split(key, 6)
    return {
        # in_proj -> [z (din), xBC (din + 2n), dt (h)]
        "in_proj": linear_init(ks[0], d, 2 * din + 2 * n + h, (None, "mlp")),
        "conv_w": param(ks[1], (cfg.ssm_conv, conv_ch), (None, "mlp"),
                        scale=1.0),
        "conv_b": param(ks[2], (conv_ch,), ("mlp",), init="zeros"),
        "A_log": param(ks[3], (h,), (None,), init="ones"),
        "D": param(ks[4], (h,), (None,), init="ones"),
        "dt_bias": param(ks[5], (h,), (None,), init="zeros"),
        "norm": rmsnorm_init(jax.random.fold_in(key, 7), din, ("mlp",)),
        "out_proj": linear_init(jax.random.fold_in(key, 8), din, d,
                                ("mlp", None)),
    }


def _split_proj(cfg, proj):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xbc = proj[..., din:2 * din + 2 * n]
    dt = proj[..., 2 * din + 2 * n:]
    return z, xbc, dt


def _conv_train(params, xbc, compute_dtype):
    """Causal depthwise conv, kernel k, over (B, S, C)."""
    w = leaf(params["conv_w"]).astype(jnp.float32)          # (k, C)
    k = w.shape[0]
    pad = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    out = out + leaf(params["conv_b"]).astype(jnp.float32)
    return jax.nn.silu(out).astype(compute_dtype)


def _ssd_chunked(x, dt, a_log, b_in, c_in):
    """Chunked SSD scan.

    x: (B,S,H,P)  dt: (B,S,H)  a_log: (H,)  b_in/c_in: (B,S,N).
    Returns y: (B,S,H,P).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    l = min(_CHUNK, s)
    assert s % l == 0, (s, l)
    nc = s // l

    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,) < 0
    la = dt.astype(jnp.float32) * a[None, None, :]           # (B,S,H) <= 0
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    lac = la.reshape(bsz, nc, l, h)
    cum = jnp.cumsum(lac, axis=2)                            # (B,nc,L,H)
    total = cum[:, :, -1, :]                                 # (B,nc,H)
    xc = xdt.reshape(bsz, nc, l, h, p)
    bc = b_in.reshape(bsz, nc, l, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, l, n).astype(jnp.float32)

    # ---- intra-chunk (attention-dual) ------------------------------------
    # scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) for j <= i
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)               # (B,nc,L,L)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, -jnp.inf)
    w = cb[..., None] * jnp.exp(decay)                       # (B,nc,L,L,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # ---- chunk states + inter-chunk scan ---------------------------------
    # S_c = sum_j exp(total - cum_j) B_j (x dt)_j  : (B,nc,H,N,P)
    wts = jnp.exp(total[:, :, None, :] - cum)                # (B,nc,L,H)
    s_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, wts, xc)

    def scan_fn(hprev, inp):
        s_chunk, tot = inp                                   # (B,H,N,P),(B,H)
        hnew = hprev * jnp.exp(tot)[:, :, None, None] + s_chunk
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, h_prev = jax.lax.scan(scan_fn, h0,
                             (jnp.moveaxis(s_c, 1, 0),
                              jnp.moveaxis(total, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # (B,nc,H,N,P)

    # y_inter[i] = exp(cum_i) * C_i . h_prev(chunk)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         cc, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y


def ssm_apply(params, xres, cfg: ArchConfig, policy, compute_dtype, *,
              cache=None, cache_pos=None):
    """Mamba2 block.  Train: cache None.  Decode: cache {'conv','h'}."""
    bsz, s, _ = xres.shape
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim

    proj = linear(params["in_proj"], xres, policy, compute_dtype)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + leaf(params["dt_bias"]).astype(jnp.float32))

    new_cache = None
    if cache is None:
        xbc = _conv_train(params, xbc, compute_dtype)
        xs = xbc[..., :din].reshape(bsz, s, h, p)
        b_in = xbc[..., din:din + n]
        c_in = xbc[..., din + n:]
        y = _ssd_chunked(xs, dt, leaf(params["A_log"]), b_in, c_in)
    else:
        # single-token decode: roll conv tail, one recurrence step
        conv_tail = cache["conv"]                            # (B, k-1, C)
        window = jnp.concatenate(
            [conv_tail, xbc.astype(conv_tail.dtype)], axis=1)  # (B,k,C)
        w = leaf(params["conv_w"]).astype(jnp.float32)
        out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
        out = jax.nn.silu(out + leaf(params["conv_b"]).astype(jnp.float32))
        xs = out[:, :din].reshape(bsz, h, p)
        b_in = out[:, din:din + n]
        c_in = out[:, din + n:]
        a = -jnp.exp(leaf(params["A_log"]).astype(jnp.float32))
        dt1 = dt[:, 0, :]                                    # (B,H)
        decay = jnp.exp(dt1 * a[None, :])                    # (B,H)
        upd = jnp.einsum("bn,bhp->bhnp", b_in, xs * dt1[..., None])
        h_new = cache["h"] * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", c_in, h_new)[:, None]  # (B,1,H,P)
        new_cache = {"conv": window[:, 1:, :], "h": h_new}
        xs = xs[:, None]                                     # (B,1,H,P)

    y = y + leaf(params["D"]).astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(bsz, -1, din).astype(compute_dtype)
    gated = y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype)
    gated = rmsnorm(params["norm"], gated, cfg.norm_eps)
    out = linear(params["out_proj"], gated, policy, compute_dtype)
    return out, new_cache


def ssm_cache_init(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    din, n = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * n), dtype),
        "h": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
                       jnp.float32),
    }
