"""Model zoo: composable blocks + assembly for all assigned architectures."""
from repro.models.common import ArchConfig
from repro.models.lm import (forward_prefill, forward_train, init_cache,
                             init_params, serve_step)

__all__ = ["ArchConfig", "forward_prefill", "forward_train", "init_cache",
           "init_params", "serve_step"]
