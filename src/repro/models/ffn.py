"""Dense FFN (SwiGLU/GELU) and MoE with sort-based ragged dispatch.

MoE uses argsort + ``jax.lax.ragged_dot`` (MegaBlocks-style grouped GEMM,
no GShard dispatch-einsum overhead, no token dropping) — expert weights
carry the 'experts' logical axis for expert parallelism.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (ArchConfig, act_fn, leaf, linear,
                                 linear_init, param)


def ffn_init(key, cfg: ArchConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": linear_init(ks[0], cfg.d_model, d_ff, (None, "mlp")),
         "w_down": linear_init(ks[1], d_ff, cfg.d_model, ("mlp", None))}
    if cfg.act == "silu":                      # gated (SwiGLU)
        p["w_gate"] = linear_init(ks[2], cfg.d_model, d_ff, (None, "mlp"))
    return p


def ffn_apply(params, x, cfg: ArchConfig, policy, compute_dtype):
    up = linear(params["w_up"], x, policy, compute_dtype)
    if "w_gate" in params:
        gate = linear(params["w_gate"], x, policy, compute_dtype)
        h = jax.nn.silu(gate) * up           # compute-dtype elementwise:
    else:                                    # f32 here becomes a stacked
        h = act_fn(cfg.act)(up)              # f32 scan residual
    return linear(params["w_down"], h, policy, compute_dtype)


# --------------------------------------------------------------------------
# grouped GEMM with a dtype-correct VJP
#
# jax 0.4.x's ragged_dot transpose emits its cotangent in
# preferred_element_type (f32) instead of the primal operand dtype; when
# the SAME bf16 activation feeds two ragged_dots inside a scanned layer
# stack, the scan transpose adds a bf16 and an f32 cotangent for one
# variable and trips `assert core.typematch` (the MoE-smoke AssertionError
# at steps.py:47).  This custom_vjp computes both gradients explicitly —
# dx as a ragged_dot against w^T, dw as a per-group masked einsum — and
# casts each cotangent back to its primal dtype.
# --------------------------------------------------------------------------

@jax.custom_vjp
def _grouped_mm(x, w, group_sizes):
    """ragged_dot with f32 accumulation: (T, d) @ (E, d, f) -> (T, f),
    rows of x grouped by expert via ``group_sizes`` (sums to T)."""
    return jax.lax.ragged_dot(x, w, group_sizes,
                              preferred_element_type=jnp.float32)


def _grouped_mm_fwd(x, w, group_sizes):
    return _grouped_mm(x, w, group_sizes), (x, w, group_sizes)


def _grouped_mm_bwd(res, dy):
    x, w, group_sizes = res
    e, d, f = w.shape
    dy32 = dy.astype(jnp.float32)
    dx = jax.lax.ragged_dot(dy32, jnp.swapaxes(w, 1, 2).astype(jnp.float32),
                            group_sizes,
                            preferred_element_type=jnp.float32)
    # dw[e] = x_g^T @ dy_g per group: segment-summed outer products at
    # forward-matmul FLOP cost, chunked over rows so the transient
    # (chunk, d, f) outer never materializes at full T
    t = x.shape[0]
    gid = jnp.repeat(jnp.arange(e), group_sizes, total_repeat_length=t)
    chunk = min(t, 128)
    pad = (-t) % chunk
    xb = jnp.pad(x.astype(jnp.float32), ((0, pad), (0, 0))
                 ).reshape(-1, chunk, d)
    dyb = jnp.pad(dy32, ((0, pad), (0, 0))).reshape(-1, chunk, f)
    gb = jnp.pad(gid, (0, pad)).reshape(-1, chunk)       # pad rows are 0s

    def blk(dw, args):
        xc, dc, gc = args
        outer = xc[:, :, None] * dc[:, None, :]          # (chunk, d, f)
        return dw + jax.ops.segment_sum(outer, gc, num_segments=e), None

    dw, _ = jax.lax.scan(blk, jnp.zeros((e, d, f), jnp.float32),
                         (xb, dyb, gb))
    gs_ct = jnp.zeros(group_sizes.shape, jax.dtypes.float0)
    return dx.astype(x.dtype), dw.astype(w.dtype), gs_ct


_grouped_mm.defvjp(_grouped_mm_fwd, _grouped_mm_bwd)


def moe_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": linear_init(ks[0], d, e, (None, None)),
        "w_gate": param(ks[1], (e, d, f), ("experts", None, "mlp")),
        "w_up": param(ks[2], (e, d, f), ("experts", None, "mlp")),
        "w_down": param(ks[3], (e, f, d), ("experts", "mlp", None)),
    }


def moe_apply(params, x, cfg: ArchConfig, policy, compute_dtype):
    """Dispatch: EP shard_map when a distribution context is active (the
    sort-based path below replicates under SPMD — a global argsort cannot
    be partitioned), single-device sort+ragged_dot otherwise."""
    from repro.launch import context as dist_ctx
    ctx = dist_ctx.current()
    if ctx is not None and ctx.mesh.shape.get(ctx.ep, 1) > 1:
        return moe_apply_ep(params, x, cfg, policy, compute_dtype, ctx)
    return moe_apply_local(params, x, cfg, policy, compute_dtype)


def moe_apply_local(params, x, cfg: ArchConfig, policy, compute_dtype):
    """Returns (y, aux_loss).  x: (B, S, d)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    flat = x.reshape(t, d)

    logits = linear(params["router"], flat, policy, jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (T, k)
    top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=0)
    aux = jnp.float32(e) * jnp.sum(frac_tokens * frac_probs)

    # sort token-expert pairs by expert id -> grouped GEMMs
    eid = top_e.reshape(t * k)
    order = jnp.argsort(eid)
    tok = order // k                                              # (T*k,)
    xs = jnp.take(flat, tok, axis=0).astype(compute_dtype)
    group_sizes = jnp.zeros((e,), jnp.int32).at[eid].add(1)

    def grouped(w):
        ww = policy.maybe_quantize_weights(leaf(w)).astype(compute_dtype)
        return lambda inp: _grouped_mm(inp, ww, group_sizes)

    gate = grouped(params["w_gate"])(xs)
    up = grouped(params["w_up"])(xs)
    h = (jax.nn.silu(gate) * up).astype(compute_dtype)
    out = grouped(params["w_down"])(h)                            # (T*k, d)

    w_sorted = jnp.take(top_w.reshape(t * k), order)
    out = out * w_sorted[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[tok].add(out)
    return y.reshape(b, s, d).astype(compute_dtype), aux


# --------------------------------------------------------------------------
# expert parallelism (shard_map + capacity-bounded all_to_all)
# --------------------------------------------------------------------------

def moe_apply_ep(params, x, cfg: ArchConfig, policy, compute_dtype, ctx,
                 capacity_factor: float = 2.0):
    """GShard-style EP: tokens are routed to the EP shard owning their
    expert via a capacity-bounded all_to_all, processed by a local grouped
    GEMM (ragged_dot over E/P local experts), and routed back.

    shard_map is fully manual over (dp..., ep); per-device local shapes are
    real, so the two argsorts are LOCAL sorts — this is what the auto-SPMD
    sort-based path cannot express (it replicates; see dry-run log in
    EXPERIMENTS.md).  Overflowing tokens beyond the per-peer capacity are
    dropped (standard GShard semantics; aux loss keeps load balanced).
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.compat import shard_map

    mesh, dp, ep = ctx.mesh, ctx.dp, ctx.ep
    e, k = cfg.n_experts, cfg.top_k
    p_ep = mesh.shape[ep]
    e_local = e // p_ep
    assert e % p_ep == 0, (e, p_ep)

    manual = tuple(dp) + (ep,)

    router_w = leaf(params["router"]["w"])
    wg = policy.maybe_quantize_weights(leaf(params["w_gate"]))
    wu = policy.maybe_quantize_weights(leaf(params["w_up"]))
    wd = policy.maybe_quantize_weights(leaf(params["w_down"]))

    def local_moe(x_l, router_w, wg_l, wu_l, wd_l):
        b_l, s_l, d = x_l.shape
        t = b_l * s_l
        flat = x_l.reshape(t, d)
        my_peer = jax.lax.axis_index(ep)

        logits = jnp.dot(flat.astype(jnp.float32),
                         router_w.astype(jnp.float32))          # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)                  # (T, k)
        top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        frac_tokens = jnp.mean(
            jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1))
        frac_probs = jnp.mean(probs, axis=0)
        aux = jnp.float32(e) * jnp.sum(frac_tokens * frac_probs)

        tk = t * k
        eid = top_e.reshape(tk)
        wgt = top_w.reshape(tk)
        tok = jnp.arange(tk, dtype=jnp.int32) // k
        peer = eid // e_local

        # position of each pair within its destination-peer bucket, via
        # one-hot running counts (sort-free: SPMD-friendly, O(tk * p_ep))
        cap = max(int(capacity_factor * tk / p_ep), 8)
        oh = jax.nn.one_hot(peer, p_ep, dtype=jnp.int32)        # (tk, p_ep)
        pos = (jnp.cumsum(oh, axis=0) - oh)[
            jnp.arange(tk), peer]                               # (tk,)
        keep = pos < cap

        send_x = jnp.zeros((p_ep, cap, d), compute_dtype)
        send_x = send_x.at[peer, pos].set(
            jnp.where(keep[:, None], flat[tok].astype(compute_dtype), 0),
            mode="drop")
        send_eid = jnp.full((p_ep, cap), e, jnp.int32)          # e = invalid
        send_eid = send_eid.at[peer, pos].set(
            jnp.where(keep, eid, e), mode="drop")

        recv_x = jax.lax.all_to_all(send_x, ep, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid, ep, 0, 0, tiled=False)

        # regroup received tokens into dense per-expert capacity blocks —
        # blocked batched einsum instead of ragged_dot (whose SPMD/CPU
        # lowering expands to e_local full-size masked matmuls)
        n_recv = p_ep * cap
        rx = recv_x.reshape(n_recv, d)
        reid = recv_eid.reshape(n_recv) - my_peer * e_local
        valid = (reid >= 0) & (reid < e_local)
        reid_c = jnp.where(valid, reid, e_local)
        oh2 = jax.nn.one_hot(reid_c, e_local + 1, dtype=jnp.int32)
        pos2 = (jnp.cumsum(oh2, axis=0) - oh2)[
            jnp.arange(n_recv), reid_c]
        cap_e = max(int(1.5 * n_recv / e_local), 8)
        keep2 = valid & (pos2 < cap_e)

        blocks = jnp.zeros((e_local, cap_e, d), compute_dtype)
        blocks = blocks.at[reid_c, pos2].set(
            jnp.where(keep2[:, None], rx, 0), mode="drop")

        def expert_mm(w_l, inp):                                # (E_l,C,d)@(E_l,d,f)
            return jax.lax.dot_general(
                inp, w_l.astype(compute_dtype),
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)

        h = jax.nn.silu(expert_mm(wg_l, blocks)) * expert_mm(wu_l, blocks)
        hb = expert_mm(wd_l, h.astype(compute_dtype))           # (E_l,C,d)
        out_rows = jnp.where(keep2[:, None],
                             hb[reid_c, pos2].astype(compute_dtype), 0)
        out = out_rows.reshape(p_ep, cap, d)
        back = jax.lax.all_to_all(out, ep, 0, 0, tiled=False)   # (p_ep,cap,d)

        contrib = back[peer, pos].astype(jnp.float32)           # (tk, d)
        contrib = jnp.where(keep[:, None], contrib, 0.0)
        contrib = contrib * wgt[:, None]
        y = jnp.zeros((t, d), jnp.float32).at[tok].add(contrib)
        return (y.reshape(b_l, s_l, d).astype(compute_dtype),
                aux[None])

    seq_spec = ctx.seq
    x_spec = P(dp if dp else None, seq_spec, None)
    aux_spec = P(manual)                     # stack per-shard aux values
    y, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(x_spec, P(), P(ep, None, None), P(ep, None, None),
                  P(ep, None, None)),
        out_specs=(x_spec, aux_spec),
        axis_names=set(manual),
        check_vma=False)(x, router_w, wg, wu, wd)
    return y, jnp.mean(aux)
