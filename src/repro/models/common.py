"""Shared model substrate: ArchConfig, numeric-policy-aware Linear, norms,
rotary embeddings, initializers.

Functional style: every module is (init(key, ...) -> params, apply(params,
x, ...) -> y) over plain dict pytrees, with explicit dtypes everywhere
(x64 is globally enabled for the posit core, so nothing may rely on dtype
defaults).  Each param leaf carries a logical-axis annotation consumed by
``repro.launch.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import Policy, get_policy


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "silu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # local/global attention pattern (gemma3: 5 local : 1 global)
    local_window: int = 0
    local_ratio: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (Mamba2/SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    hybrid_attn_every: int = 0     # zamba2: shared attn block cadence
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0
    # VLM stub frontend
    vis_tokens: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    policy: str = "bf16"

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind for the decoder stack."""
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm", "hybrid"):
                kinds.append("ssm")
            elif self.local_ratio and (i + 1) % (self.local_ratio + 1) != 0:
                kinds.append("local")
            else:
                kinds.append("attn")
        return kinds

    def get_policy(self) -> Policy:
        return get_policy(self.policy)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid") or (
            self.local_ratio > 0 and self.local_window > 0)


# --------------------------------------------------------------------------
# param helpers
# --------------------------------------------------------------------------

class Axes(tuple):
    """Logical-axis annotation that travels inside the param pytree but has
    NO JAX leaves (registered with the names as static aux data), so grad /
    optimizer tree-maps pass straight through it."""


jax.tree_util.register_pytree_node(
    Axes, lambda a: ((), tuple(a)), lambda aux, _: Axes(aux))


def param(key, shape, axes: Sequence[Optional[str]], scale: float = 1.0,
          dtype=jnp.float32, init: str = "normal"):
    """A param leaf + its logical sharding axes (stored side-by-side)."""
    if init == "normal":
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        std = scale / np.sqrt(fan_in)
        w = jax.random.normal(key, shape, dtype=jnp.float32) * std
    elif init == "zeros":
        w = jnp.zeros(shape, jnp.float32)
    elif init == "ones":
        w = jnp.ones(shape, jnp.float32)
    else:
        raise ValueError(init)
    return {"w": w.astype(dtype), "axes": Axes(axes)}


def leaf(p):
    if "qw" in p:          # posit-quantized leaf (serving.quantize)
        from repro.serving.quantize import dequant_leaf
        return dequant_leaf(p)
    return p["w"]


def is_param(x) -> bool:
    return isinstance(x, dict) and set(x) == {"w", "axes"}


def map_params(fn, tree):
    """Map fn(leaf_dict) over all param leaves of a model pytree."""
    if is_param(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: map_params(fn, v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(map_params(fn, v) for v in tree)
    return tree


# --------------------------------------------------------------------------
# basic layers
# --------------------------------------------------------------------------

def rmsnorm_init(key, d, axes=("embed",)):
    return {"scale": param(key, (d,), axes, init="ones")}


def rmsnorm(params, x, eps):
    """f32 only in the reduction: x-shaped f32 elementwise intermediates
    inside checkpointed scan bodies get stacked as f32 residuals by the
    scan linearizer (verified minimal repro; EXPERIMENTS.md §Perf), so the
    normalize/scale multiplies stay in the compute dtype."""
    dt = x.dtype
    var = (jnp.einsum("...d,...d->...", x, x,
                      preferred_element_type=jnp.float32)
           / x.shape[-1])[..., None]
    r = jax.lax.rsqrt(var + jnp.float32(eps)).astype(dt)
    return x * r * leaf(params["scale"]).astype(dt)


def linear_init(key, d_in, d_out, axes, bias=False, scale=1.0):
    p = {"w": param(key, (d_in, d_out), axes, scale=scale)}
    if bias:
        p["b"] = param(key, (d_out,), (axes[-1],), init="zeros")
    return p


def linear(params, x, policy: Policy, compute_dtype):
    """Policy-aware dense layer — the paper's technique enters here: with a
    posit policy, weights and activations are rounded to the Posit(32,2)
    lattice (simulated quantization; the Pallas kernel is the native
    execution of the same semantics on TPU)."""
    if "qw" in params["w"]:
        # posit-quantized weight leaf (serving.quantize): the words are
        # decoded inside the jit (xla backend) or consumed directly by
        # the Pallas GEMM (pallas backend); the per-channel pow2 scale
        # is folded into the output exactly.  Policy weight/activation
        # quantization does not stack on top — the leaf IS the lattice.
        from repro.serving.quantize import quant_matmul
        y = quant_matmul(x, params["w"], compute_dtype)
        if "b" in params:
            y = y + leaf(params["b"]).astype(compute_dtype)
        return y
    w = leaf(params["w"])
    w = policy.maybe_quantize_weights(w)
    x = policy.maybe_quantize_acts(x)
    # Train: output in compute dtype (the MXU accumulates bf16 dots in f32
    # internally; an f32 *output* becomes a stacked f32 scan residual).
    # Decode: f32 outputs (see DistContext.f32_partials).
    from repro.launch import context as dist_ctx
    ctx = dist_ctx.current()
    pref = jnp.float32 if (ctx is not None and ctx.f32_partials) \
        else compute_dtype
    y = jnp.dot(x.astype(compute_dtype), w.astype(compute_dtype),
                preferred_element_type=pref).astype(compute_dtype)
    if "b" in params:
        y = y + leaf(params["b"]).astype(compute_dtype)
    return y


def embed_init(key, vocab, d):
    return {"table": param(key, (vocab, d), ("vocab", "embed"), scale=1.0)}


def embed(params, ids, compute_dtype):
    """Vocab-parallel embedding lookup.

    With a vocab-sharded table, a plain gather makes SPMD replicate the
    indices AND the (tokens, d) output on every vocab shard; the standard
    fix (Megatron vocab-parallel embedding) is a local masked gather +
    psum, which needs manual sharding — done here with shard_map over the
    'model' axis when a distribution context is active."""
    from repro.launch import context as dist_ctx
    from repro.launch.compat import shard_map
    from jax.sharding import PartitionSpec as P
    table = leaf(params["table"])
    ctx = dist_ctx.current()
    n_sh = ctx.mesh.shape.get("model", 1) if ctx is not None else 1
    if ctx is None or n_sh == 1 or table.shape[0] % n_sh:
        return jnp.take(table.astype(compute_dtype), ids, axis=0)

    v_local = table.shape[0] // n_sh
    # vocab and sequence share the 'model' axis: gather locally against the
    # full (model-replicated) id list, then reduce-scatter the partial sums
    # over the sequence dim (Megatron sequence-parallel embedding)
    seq_shard = ctx.seq is not None and ids.shape[1] % n_sh == 0

    def local_lookup(tab, ids_l):
        shard = jax.lax.axis_index("model")
        adj = ids_l - shard * v_local
        valid = (adj >= 0) & (adj < v_local)
        g = jnp.take(tab.astype(compute_dtype),
                     jnp.clip(adj, 0, v_local - 1), axis=0)
        g = jnp.where(valid[..., None], g,
                      jnp.zeros((), compute_dtype))
        # psum in f32: XLA CPU's AllReducePromotion CHECK-fails when it
        # clones the copy-rooted reducer a bf16 psum gets (bisected during
        # the dry-run; see EXPERIMENTS.md §Perf)
        g = g.astype(jnp.float32)
        if seq_shard:
            out = jax.lax.psum_scatter(g, "model", scatter_dimension=1,
                                       tiled=True)
        else:
            out = jax.lax.psum(g, "model")
        return out.astype(compute_dtype)

    dp_spec = ctx.dp if ctx.dp else None
    out = shard_map(
        local_lookup, mesh=ctx.mesh,
        in_specs=(P("model", None), P(dp_spec, None)),
        out_specs=P(dp_spec, "model" if seq_shard else None, None),
        axis_names={"model"} | set(ctx.dp),
        check_vma=False)(table, ids)
    return out


def unembed(params, x, compute_dtype):
    t = leaf(params["table"]).astype(compute_dtype)
    return jnp.dot(x, t.T, preferred_element_type=jnp.float32)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = (jnp.float32(theta)
            ** -(jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)   # (S,1,half): small
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
