"""Model assembly for all assigned architecture families.

families: dense | moe (dense attn + MoE FFN) | ssm (pure Mamba2) |
hybrid (Mamba2 + weight-shared attention block, Zamba2-style) |
encdec (Whisper: bidirectional encoder + cross-attending decoder) |
vlm (stub visual tokens prepended to an LM backbone, InternVL2-style).

Layer stacks are SCANNED, not Python-unrolled: layers are grouped into one
*period* (gemma3: 5 local + 1 global; zamba2: 6 mamba + shared attn; else
period 1) whose params are stacked with a leading (n_layers/period) dim and
driven by nested lax.scan — compile time is O(period), not O(n_layers),
and two-level scan + jax.checkpoint gives O(sqrt L) live activations
(required for llama3-405b train_4k; DESIGN.md §3.5).

Public surface:
    init_params(key, cfg)                    -> param pytree (+ Axes)
    forward_train(params, batch, cfg, remat) -> (loss, metrics)
    forward_prefill(params, batch, cfg)      -> last-position logits
    init_cache(cfg, batch, seq_len)          -> decode cache pytree
    serve_step(params, cache, tok, pos, cfg) -> (logits, cache)

Modality frontends are STUBS per the assignment: batches carry precomputed
frame/patch embeddings ("frames" / "vis") at d_model.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ArchConfig, embed, embed_init, leaf,
                                 param, rmsnorm, rmsnorm_init, unembed)


# --------------------------------------------------------------------------
# periods and stacking
# --------------------------------------------------------------------------

def period_of(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        return cfg.hybrid_attn_every
    if cfg.local_ratio:
        return cfg.local_ratio + 1
    return 1


def _best_split(n: int) -> tuple[int, int]:
    """Factor n = g * m with g as close to sqrt(n) as possible."""
    best = (1, n)
    for g in range(1, n + 1):
        if n % g == 0 and abs(g - n ** 0.5) < abs(best[0] - n ** 0.5):
            best = (g, n // g)
    return best


def slot_kinds(cfg: ArchConfig) -> list[str]:
    return cfg.layer_kinds()[:period_of(cfg)]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig, kind: str, cross: bool = False):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"ln1": rmsnorm_init(ks[0], cfg.d_model)}
    if kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg)
        return p
    p["attn"] = attn_mod.attn_init(ks[1], cfg)
    p["ln2"] = rmsnorm_init(ks[2], cfg.d_model)
    if cfg.n_experts and kind != "shared":
        p["moe"] = ffn_mod.moe_init(ks[3], cfg)
    else:
        p["ffn"] = ffn_mod.ffn_init(ks[3], cfg)
    if cross:
        p["lnx"] = rmsnorm_init(ks[4], cfg.d_model)
        p["xattn"] = attn_mod.attn_init(ks[5], cfg, cross=True)
    return p


def init_params(key, cfg: ArchConfig):
    per = period_of(cfg)
    np_ = cfg.n_layers // per
    assert cfg.n_layers % per == 0, (cfg.n_layers, per)
    kinds = slot_kinds(cfg)
    cross = cfg.family == "encdec"
    ks = jax.random.split(key, 8)

    params: dict[str, Any] = {"embed": embed_init(ks[0], cfg.vocab,
                                                  cfg.d_model)}
    # stacked slots: slot j holds leaves with leading dim np_
    slot_keys = jax.random.split(ks[1], per * np_).reshape(per, np_, 2)
    params["layers"] = [
        jax.vmap(lambda k, j=j: _layer_init(k, cfg, kinds[j], cross=cross)
                 )(slot_keys[j])
        for j in range(per)]
    params["final_norm"] = rmsnorm_init(ks[2], cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"] = {
            "w": param(ks[3], (cfg.d_model, cfg.vocab), (None, "vocab"))}
    if cfg.family == "hybrid":
        params["shared_attn"] = _layer_init(ks[4], cfg, "shared")
    if cfg.family == "encdec":
        enc_keys = jax.random.split(ks[5], cfg.enc_layers)
        params["enc"] = {
            "pos": param(ks[6], (cfg.enc_seq, cfg.d_model),
                         (None, "embed"), scale=0.02),
            "layers": jax.vmap(
                lambda k: _layer_init(k, cfg, "attn"))(enc_keys),
            "final_norm": rmsnorm_init(ks[7], cfg.d_model),
        }
    return params


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _block(params, x, cfg, policy, dtype, kind, *, positions, cache=None,
           cache_pos=None, cross_kv=None, causal=True):
    """One residual block; returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache: dict[str, Any] = {}
    if kind == "ssm":
        h, c = ssm_mod.ssm_apply(
            params["ssm"], rmsnorm(params["ln1"], x, cfg.norm_eps), cfg,
            policy, dtype,
            cache=None if cache is None else cache["ssm"],
            cache_pos=cache_pos)
        if c is not None:
            new_cache["ssm"] = c
        return x + h, new_cache, aux

    window = cfg.local_window if kind == "local" else 0
    h, c = attn_mod.attn_apply(
        params["attn"], rmsnorm(params["ln1"], x, cfg.norm_eps), cfg, policy,
        dtype, positions=positions, causal=causal, window=window,
        kv_cache=None if cache is None else cache["kv"], cache_pos=cache_pos)
    if c is not None:
        new_cache["kv"] = c
    x = x + h
    if "xattn" in params:
        h, _ = attn_mod.attn_apply(
            params["xattn"], rmsnorm(params["lnx"], x, cfg.norm_eps), cfg,
            policy, dtype, positions=positions, causal=False,
            cross_kv=cross_kv)
        x = x + h
    h_in = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if "moe" in params:
        h, aux = ffn_mod.moe_apply(params["moe"], h_in, cfg, policy, dtype)
    else:
        h = ffn_mod.ffn_apply(params["ffn"], h_in, cfg, policy, dtype)
    return x + h, new_cache, aux


def _period_fwd(x, slots, shared_p, enc_out, cfg, policy, dtype, positions,
                kinds):
    """Apply one period's slots (train/prefill, no cache)."""
    aux = jnp.float32(0.0)
    for j, sp in enumerate(slots):
        ck = None
        if cfg.family == "encdec":
            ck = attn_mod.cross_kv_init(sp["xattn"], enc_out, cfg, policy,
                                        dtype)
        x, _, a = _block(sp, x, cfg, policy, dtype, kinds[j],
                         positions=positions, cross_kv=ck)
        aux += a
    if cfg.family == "hybrid" and shared_p is not None:
        x, _, _ = _block(shared_p, x, cfg, policy, dtype, "shared",
                         positions=positions)
    return x, aux


def _encoder(params, frames, cfg, policy, dtype):
    """Whisper-style bidirectional encoder (scanned) over stub embeddings."""
    se = frames.shape[1]
    x = frames.astype(dtype) + leaf(params["enc"]["pos"])[:se].astype(dtype)
    pos = jnp.arange(se, dtype=jnp.int32)

    def body(x, lp):
        y, _, _ = _block(lp, x, cfg, policy, dtype, "attn", positions=pos,
                         causal=False)
        return y, None

    x, _ = jax.lax.scan(body, x, params["enc"]["layers"])
    return rmsnorm(params["enc"]["final_norm"], x, cfg.norm_eps)


def _logits(params, x, cfg, dtype):
    if cfg.tie_embeddings:
        return unembed(params["embed"], x, dtype)
    return jnp.dot(x, leaf(params["unembed"]["w"]).astype(dtype),
                   preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# backbone (nested scan over periods)
# --------------------------------------------------------------------------

def _constrain(x):
    """Pin activation sharding (dp on batch, optional seq sharding) — SPMD
    propagation loses the batch axis through the vocab-sharded embedding
    gather without this (observed as replicated 13-64 GiB activations on
    llama3-405b; EXPERIMENTS.md §Perf)."""
    from repro.launch import context as dist_ctx
    from jax.sharding import NamedSharding, PartitionSpec as P
    ctx = dist_ctx.current()
    if ctx is None or x.ndim != 3:
        return x
    spec = P(ctx.dp if ctx.dp else None, ctx.seq, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def _backbone(params, batch, cfg: ArchConfig, remat: bool = False):
    policy = cfg.get_policy()
    dtype = jnp.dtype(policy.compute_dtype)
    tokens = batch["tokens"]
    x = _constrain(embed(params["embed"], tokens, dtype))

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encoder(params, batch["frames"], cfg, policy, dtype)
    n_vis = 0
    if cfg.family == "vlm" and "vis" in batch:
        vis = batch["vis"].astype(dtype)
        n_vis = vis.shape[1]
        x = jnp.concatenate([vis, x], axis=1)

    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    kinds = slot_kinds(cfg)
    shared = params.get("shared_attn")
    per = period_of(cfg)
    np_ = cfg.n_layers // per

    def body(x, slots):
        y, aux = _period_fwd(x, slots, shared, enc_out, cfg, policy, dtype,
                             positions, kinds)
        return _constrain(y), aux

    body_ck = jax.checkpoint(body) if remat else body

    g, m = _best_split(np_) if remat else (1, np_)

    def inner(x, slots):                       # scan over m periods
        return jax.lax.scan(body_ck, x, slots)

    if g == 1:
        x, auxs = inner(x, params["layers"])
        aux_total = jnp.sum(auxs)
    else:
        regrouped = jax.tree.map(
            lambda a: a.reshape((g, m) + a.shape[1:]), params["layers"])

        def outer_body(x, group_slots):
            y, auxs = inner(x, group_slots)
            return y, jnp.sum(auxs)

        outer = jax.checkpoint(outer_body) if remat else outer_body
        x, auxs = jax.lax.scan(outer, x, regrouped)
        aux_total = jnp.sum(auxs)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if n_vis:
        x = x[:, n_vis:, :]
    return x, aux_total


def forward_prefill(params, batch, cfg: ArchConfig):
    """Inference prefill: next-token logits for the LAST position only
    (never materializes (B,S,V))."""
    policy = cfg.get_policy()
    dtype = jnp.dtype(policy.compute_dtype)
    x, _ = _backbone(params, batch, cfg, remat=False)
    return _logits(params, x[:, -1:, :], cfg, dtype)[:, 0, :]


def _chunked_ce(params, x, targets, cfg, dtype, max_chunk_elems=2 ** 26):
    """Cross-entropy scanned over sequence chunks so the (tokens, vocab)
    logits tensor is never live at full size (llama3/gemma3-class vocabs
    at 4k x 256 tokens would otherwise dominate HBM).  The chunk body is
    rematerialized in the backward pass."""
    b, s, _ = x.shape
    chunk = max(min(s, max_chunk_elems // max(cfg.vocab, 1)), 1)
    while s % chunk:
        chunk -= 1
    n = s // chunk
    xc = x.reshape(b, n, chunk, -1).swapaxes(0, 1)         # (n,B,c,d)
    tc = targets.reshape(b, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        xx, tt = inp
        logits = _logits(params, xx, cfg, dtype)           # (B,c,V) f32
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        mask = (tt >= 0).astype(jnp.float32)
        tot, cnt = carry
        return (tot + jnp.sum((logz - gold) * mask),
                cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xc, tc))
    return tot / jnp.maximum(cnt, 1.0), cnt


def forward_train(params, batch, cfg: ArchConfig, remat: bool = False):
    """Returns (loss, metrics)."""
    policy = cfg.get_policy()
    dtype = jnp.dtype(policy.compute_dtype)
    x, aux_total = _backbone(params, batch, cfg, remat=remat)
    loss, ntok = _chunked_ce(params, x, batch["targets"], cfg, dtype)
    if cfg.n_experts:
        loss = loss + 0.01 * aux_total / cfg.n_layers
    return loss, {"loss": loss, "ntokens": ntok}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def _slot_cache(cfg: ArchConfig, kind: str, batch: int, seq_len: int, dtype):
    if kind == "ssm":
        return {"ssm": ssm_mod.ssm_cache_init(cfg, batch, dtype)}
    s_cache = seq_len
    if kind == "local" and cfg.local_window:
        s_cache = min(seq_len, cfg.local_window)
    return {"kv": {
        "k": jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, s_cache, cfg.n_kv_heads, cfg.d_head), dtype),
    }}


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    """Decode cache: stacked per slot (leading dim = n_layers/period)."""
    per = period_of(cfg)
    np_ = cfg.n_layers // per
    kinds = slot_kinds(cfg)

    def stack(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (np_,) + a.shape).copy(), tree)

    cache: dict[str, Any] = {"layers": [
        stack(_slot_cache(cfg, kinds[j], batch, seq_len, dtype))
        for j in range(per)]}
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        cache["shared"] = stack(_slot_cache(cfg, "shared", batch, seq_len,
                                            dtype))
    return cache


def serve_step(params, cache, tokens, pos, cfg: ArchConfig):
    """One decode step.  tokens: (B,1) int32; pos: scalar int32 (absolute)
    or (B,) int32 (per-request absolute positions — the continuous-batching
    engine decodes requests at different depths in one step).
    Returns (logits (B,V), new_cache)."""
    policy = cfg.get_policy()
    dtype = jnp.dtype(policy.compute_dtype)
    x = embed(params["embed"], tokens, dtype)
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos.reshape(-1, 1) if pos.ndim else jnp.reshape(pos, (1,))
    kinds = slot_kinds(cfg)
    shared = params.get("shared_attn")

    def body(x, scanned):
        slots, slot_caches, shared_cache, xkv = scanned
        new_caches = []
        for j, sp in enumerate(slots):
            ck = xkv if cfg.family == "encdec" else None
            x, nc, _ = _block(sp, x, cfg, policy, dtype, kinds[j],
                              positions=positions, cache=slot_caches[j],
                              cache_pos=pos, cross_kv=ck)
            new_caches.append(nc if nc else slot_caches[j])
        new_shared = shared_cache
        if cfg.family == "hybrid" and shared is not None:
            x, nc, _ = _block(shared, x, cfg, policy, dtype, "shared",
                              positions=positions, cache=shared_cache,
                              cache_pos=pos)
            new_shared = nc
        return x, (new_caches, new_shared)

    per = period_of(cfg)
    slot_caches = cache["layers"]
    shared_cache = cache.get("shared")
    xkv = cache.get("cross_kv")
    if cfg.family == "encdec":
        assert xkv is not None, (
            "encdec serve_step needs cache['cross_kv'] (stacked encoder "
            "K/V) — build it with serving.prefill")
    if shared_cache is None:           # dummy for scan structure
        shared_cache = jnp.zeros((cfg.n_layers // per,), jnp.float32)
    if xkv is None:
        xkv = jnp.zeros((cfg.n_layers // per,), jnp.float32)

    def scan_body(x, scanned):
        return body(x, scanned)

    x, (new_layer_caches, new_shared) = jax.lax.scan(
        scan_body, x, (params["layers"], slot_caches, shared_cache, xkv))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, x[:, 0, :], cfg, dtype)
    new_cache = dict(cache)
    new_cache["layers"] = new_layer_caches
    if "shared" in cache:
        new_cache["shared"] = new_shared
    return logits, new_cache
