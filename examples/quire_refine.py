"""Quire-exact iterative refinement demo (beyond paper Fig. 7).

Factorize once in Posit(32,2), then recover f64-class solutions with the
posit-standard quire: exact residuals, one rounding each, and a
double-posit (hi + lo) iterate.  The multi-RHS block shows the
"many scenarios" path: one factorization, a vmapped refinement over a
batch of right-hand sides.

    PYTHONPATH=src python examples/quire_refine.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import posit as P
from repro.lapack import refine, solve
from repro.lapack.error_eval import make_general, refinement_study

N = 256

print(f"== paper §5.1 protocol, N={N}, phi=0 ensemble ==")
print(f"{'algo':10s} {'e_plain':>12s} {'e_ir':>12s} {'digits gained':>14s}")
for algo in ("lu", "cholesky"):
    r = refinement_study(N, 1.0, algo, nb=32, iters=3)
    print(f"{algo:10s} {r.e_plain:12.3e} {r.e_ir:12.3e} "
          f"{r.digits_gained:+14.2f}")

print("\n== one factorization, many right-hand sides (vmapped IR) ==")
a64 = make_general(N, 1.0, seed=7)
rng = np.random.default_rng(8)
nrhs = 16
b64 = a64 @ rng.standard_normal((N, nrhs))          # 16 scenarios
a_p = P.from_float64(jnp.asarray(a64))
b_p = P.from_float64(jnp.asarray(b64))

(x_hi, x_lo), (lu, ipiv) = refine.rgesv_ir(a_p, b_p, iters=3, nb=32)
x64 = np.asarray(refine.pair_to_float64(x_hi, x_lo))
a64q = np.asarray(P.to_float64(a_p))
b64q = np.asarray(P.to_float64(b_p))
res = np.linalg.norm(b64q - a64q @ x64, axis=0) / np.linalg.norm(b64q, axis=0)
print(f"batched backward errors over {nrhs} RHS: "
      f"max={res.max():.3e} median={np.median(res):.3e}")
x_plain = np.asarray(P.to_float64(solve.rgetrs(lu, ipiv, b_p[:, 0])))
e_plain = (np.linalg.norm(b64q[:, 0] - a64q @ x_plain)
           / np.linalg.norm(b64q[:, 0]))
print(f"(plain posit32 solve for comparison: {e_plain:.3e})")

print("\n== mixed precision: factorize p16e1, refine with p32e2 quire ==")
# The HPL-AI play (DESIGN.md §8): the O(n^3) factorization runs in the
# cheap half-width format; quire-exact p32e2 residual sweeps recover the
# full-width floor.  Same answer, cheaper factorization.
(m_hi, m_lo), _ = refine.rgesv_mp(a_p, b_p[:, 0], iters=8, nb=32)
x_mp = np.asarray(refine.pair_to_float64(m_hi, m_lo))
e_mp = (np.linalg.norm(b64q[:, 0] - a64q @ x_mp)
        / np.linalg.norm(b64q[:, 0]))
print(f"rgesv_mp (p16e1 factor + p32e2 refine): {e_mp:.3e} "
      f"(vs full-width IR {res[0]:.3e})")
