"""Batched serving demo: prefill + greedy decode over the ring-buffer
KV/state caches, on two architecture families (attention + SSM).

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import generate

for arch in ("qwen2-0.5b", "mamba2-780m"):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.array([[5, 6, 7, 8], [1, 2, 3, 4]], np.int32)
    out = generate(params, cfg, prompts, max_new=8)
    print(f"{arch}: prompts {prompts.tolist()} -> generated {out.tolist()}")
