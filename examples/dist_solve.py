"""Distributed posit solve demo: 8 host devices, bit-identical words.

Factor A in Posit(32,2) across a 2x4 device grid (block-cyclic layout,
SUMMA trailing updates), refine with DISTRIBUTED quire residuals
(limb-plane psum), and check the refined pair is word-for-word the
single-device result — the posit determinism story surviving
distribution.

    PYTHONPATH=src python examples/dist_solve.py
"""
import os

# must precede jax backend init
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import posit as P
from repro.lapack import refine
from repro.dist import distribute, make_grid_mesh, p_rgesv_ir, pdgemm

N, NB, NRHS = 128, 32, 4
print(f"devices: {len(jax.devices())}")
mesh = make_grid_mesh(2, 4)

rng = np.random.default_rng(0)
a64 = rng.standard_normal((N, N))
x_true = rng.standard_normal((N, NRHS))
a_p = P.from_float64(jnp.asarray(a64))
b_p = P.from_float64(jnp.asarray(a64 @ x_true))

a_d = distribute(a_p, mesh, NB)

print(f"\n== distributed IR solve, N={N}, grid 2x4, nb={NB}, "
      f"{NRHS} right-hand sides ==")
(x_hi, x_lo), (lu_d, ipiv) = p_rgesv_ir(a_d, b_p, iters=3)

a64q = np.asarray(P.to_float64(a_p))
b64q = np.asarray(P.to_float64(b_p))
x64 = np.asarray(refine.pair_to_float64(x_hi, x_lo))
res = np.linalg.norm(b64q - a64q @ x64, axis=0) / np.linalg.norm(b64q, axis=0)
print("relative residuals per RHS:", np.array2string(res, precision=2))

print("\n== bit-identity vs single-device rgesv_ir ==")
(x_hi_s, x_lo_s), (lu_s, _) = refine.rgesv_ir(a_p, b_p, iters=3, nb=NB)
print("x_hi words identical:",
      np.array_equal(np.asarray(x_hi), np.asarray(x_hi_s)))
print("x_lo words identical:",
      np.array_equal(np.asarray(x_lo), np.asarray(x_lo_s)))
print("LU words identical:  ",
      np.array_equal(np.asarray(lu_d.gather()), np.asarray(lu_s)))

print("\n== distributed GEMM check: L@U in quire k-split schedule ==")
c_d = pdgemm(a_d, a_d, backend="quire_exact", k_split=True)
from repro.kernels.ops import rgemm
print("pdgemm(k_split) identical:",
      np.array_equal(np.asarray(c_d.gather()),
                     np.asarray(rgemm(a_p, a_p, backend="quire_exact"))))
