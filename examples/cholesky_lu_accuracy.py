"""Reproduce paper Fig. 7 (the accuracy headline) across sigma.

    PYTHONPATH=src python examples/cholesky_lu_accuracy.py
"""
from repro.lapack.error_eval import backward_error_study

print(f"{'algo':10s} {'sigma':>8s} {'e_posit':>12s} {'e_binary32':>12s} "
      f"{'digits':>8s}")
for algo in ("cholesky", "lu"):
    for sigma in (1e-2, 1.0, 1e2, 1e4):
        r = backward_error_study(64, sigma, algo, nb=16,
                                 gemm_backend="faithful")
        print(f"{algo:10s} {sigma:8g} {r.e_posit:12.3e} "
              f"{r.e_binary32:12.3e} {r.digits:+8.2f}")
print("\npositive digits = Posit(32,2) more accurate than binary32 "
      "(paper: ~+0.5 Cholesky / ~+0.8 LU in the golden zone)")
