"""positscope walkthrough: watch a mixed-precision solve converge.

Runs ``rgesv_mp`` (p16e1 factorization + p32e2 quire-exact refinement)
over the paper's §5.1 sigma grid with the observability layer on:

* per-sweep convergence trace — residual norm, digits gained, and the
  golden-zone occupancy of the residual (the ``ir.sweep`` series);
* operand golden-zone occupancy per sigma — the measurable mechanism
  behind the paper's "accuracy depends on operand scale" effect
  (posit(32,2) keeps its maximal 27 fraction bits only for
  |x| in [1/16, 16));
* a Chrome trace_event file (TRACE_observe_solve.json) — open it in
  Perfetto (https://ui.perfetto.dev) or chrome://tracing to see the
  factorization / sweep span timeline.

Run:  PYTHONPATH=src python examples/observe_solve.py
"""
import json

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.core import posit as P
from repro.core.formats import P16E1, P32E2
from repro.lapack.refine import pair_to_float64, rgesv_mp

# --- the §5.1 protocol over a sigma grid ---------------------------------
# x_sol = (1/sqrt(n)) ones, b = A x_sol in f64; solve the posit-held
# system and measure the backward error against what the solver saw.
n = 64
sigmas = (1e-4, 1e-2, 1.0, 1e2, 1e4)
rng = np.random.default_rng(0)

lo, hi = obs.golden_zone_bounds(P32E2)
print(f"golden zone of {P32E2.name}: |x| in [{lo:g}, {hi:g})   "
      f"(factor format {P16E1.name}: "
      f"[{obs.golden_zone_bounds(P16E1)[0]:g}, "
      f"{obs.golden_zone_bounds(P16E1)[1]:g}))\n")

collector = obs.Collector()
for sigma in sigmas:
    a64 = rng.standard_normal((n, n)) * sigma + n * sigma * np.eye(n)
    b64 = a64 @ np.full(n, 1.0 / np.sqrt(n))
    a_p = P.from_float64(jnp.asarray(a64))
    b_p = P.from_float64(jnp.asarray(b64))

    with obs.scoped(collector) as m:
        with obs.span("solve", sigma=sigma):
            (x_hi, x_lo), _ = rgesv_mp(a_p, b_p, iters=6, nb=16)

    occ = obs.golden_zone_fraction(a_p)
    a64q = np.asarray(P.to_float64(a_p))
    b64q = np.asarray(P.to_float64(b_p))
    x = np.asarray(pair_to_float64(x_hi, x_lo))
    err = np.linalg.norm(b64q - a64q @ x) / np.linalg.norm(b64q)
    print(f"sigma={sigma:<8g} golden-zone occupancy of A: {occ:5.3f}   "
          f"backward error after refinement: {err:.2e}")
    for row in m.to_dict()["series"]["ir.sweep"]:
        print(f"    sweep {row['sweep']}: ||r|| = {row['r_norm']:.3e}   "
              f"digits gained {row['digits_gained']:+5.2f}   "
              f"r golden-zone {row['golden_frac']:.3f}   "
              f"quire carries {row['limb_carries']}")

# --- dump the span timeline ----------------------------------------------
trace_path = "TRACE_observe_solve.json"
collector.save_chrome_trace(trace_path)
n_ev = len(json.load(open(trace_path))["traceEvents"])
print(f"\nwrote {trace_path} ({n_ev} span events) — load it in Perfetto "
      "(ui.perfetto.dev) or chrome://tracing")
