"""Exact-ABFT fault tolerance, end to end (DESIGN.md §11).

Three acts, all on one CPU process:

1. a quire-checksummed GEMM detecting a seeded single-word corruption
   and recovering the bit-identical fault-free answer,
2. a protected blocked LU absorbing faults injected into its panel
   updates — the caller never sees them,
3. ``rgesv_guarded``, the graceful-degradation ladder: mixed-precision
   first, full-width refinement when the monitor says the cheap rung
   stalled, best-effort backsolve last — with a structured
   ``SolveReport`` saying which rung answered and why.

Every detection here is an exact integer mismatch (quire-limb and raw
word checksums), so there are no thresholds to tune: zero false
positives on fault-free runs, 100% detection of corrupted stored words.

    PYTHONPATH=src python examples/fault_tolerant_solve.py
"""
import numpy as np
import jax.numpy as jnp

from repro import ft
from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.lapack import decomp, refine
from repro.lapack.error_eval import make_general

N = 96
NB = 32

rng = np.random.default_rng(0)
a = P.from_float64(jnp.asarray(make_general(N, 1.0, seed=1)))
b = P.from_float64(jnp.asarray(rng.standard_normal(N)))

# -- act 1: checksummed GEMM catches a flipped stored word ------------
print("== rgemm_ft: seeded single-word corruption ==")
ref = rgemm(a, a)                         # unprotected reference words
plan = ft.make_plan(seed=7, site="rgemm.out", size=N * N)
c, _, rep = ft.rgemm_ft(a, a, plan=plan)
ok = bool(np.array_equal(np.asarray(c), np.asarray(ref)))
print(f"detections={rep.detections} retries={rep.retries} "
      f"recovered bit-identical={ok}")
assert rep.detections == 1 and ok

# -- act 2: protected LU absorbs faults in its panel updates ----------
print("\n== rgetrf_ft: faults injected into the blocked update ==")
lu_ref, piv_ref = decomp.rgetrf(a, nb=NB)
plan = ft.make_plan(seed=11, site="rgetrf.step", size=N * NB,
                    steps=N // NB)
lu, piv, rep = decomp.rgetrf_ft(a, nb=NB, plan=plan)
ok = bool(np.array_equal(np.asarray(lu), np.asarray(lu_ref))
          and np.array_equal(np.asarray(piv), np.asarray(piv_ref)))
print(f"detections={rep.detections} retries={rep.retries} "
      f"factors bit-identical={ok}")
assert rep.detections >= 1 and ok

# -- act 3: the graceful-degradation solve ladder ---------------------
print("\n== rgesv_guarded: mp -> ir -> plain ladder ==")


def residual(pair, a_p, b_p):
    x64 = np.asarray(refine.pair_to_float64(*pair))
    a64 = np.asarray(P.to_float64(a_p))
    b64 = np.asarray(P.to_float64(b_p))
    return np.linalg.norm(b64 - a64 @ x64) / np.linalg.norm(b64)


# benign matrix: the cheap mixed-precision rung converges
pair, report = refine.rgesv_guarded(a, b, nb=NB)
print(f"benign   : solver={report.solver:<9} outcome={report.outcome:<9} "
      f"sweeps={report.sweeps} rel-residual={residual(pair, a, b):.2e}")

# ill-conditioned matrix: monitor sees the narrow rung stall, escalates
u, _ = np.linalg.qr(rng.standard_normal((N, N)))
v, _ = np.linalg.qr(rng.standard_normal((N, N)))
hard64 = (u * np.logspace(0, -5, N)) @ v.T
hard = P.from_float64(jnp.asarray(hard64))
pair, report = refine.rgesv_guarded(hard, b, nb=NB)
print(f"cond 1e5 : solver={report.solver:<9} outcome={report.outcome:<9} "
      f"sweeps={report.sweeps} rel-residual={residual(pair, hard, b):.2e} "
      f"fallbacks={list(report.fallbacks)}")

# benign matrix again, now with storage faults during factorization:
# the ABFT layer repairs them before refinement ever sees the factors
plan = ft.make_plan(seed=3, site="rgetrf.step", size=N * NB,
                    steps=N // NB)
pair_f, report_f = refine.rgesv_guarded(a, b, nb=NB, plan=plan)
pair, report = refine.rgesv_guarded(a, b, nb=NB)
same = bool(np.array_equal(np.asarray(pair_f[0]), np.asarray(pair[0]))
            and np.array_equal(np.asarray(pair_f[1]), np.asarray(pair[1])))
print(f"faulted  : solver={report_f.solver:<9} outcome={report_f.outcome:<9} "
      f"detections={report_f.detections} retries={report_f.retries} "
      f"solution identical to fault-free={same}")
assert report_f.detections >= 1 and same
print("\nall recoveries bit-identical — see DESIGN.md §11 for why "
      "exact checksums make that a guarantee, not a hope")
