"""End-to-end driver at ~100M parameters (deliverable b).

A qwen2-family config scaled to ~100M params, trained for a few hundred
steps on the synthetic pipeline with checkpointing:

    PYTHONPATH=src python examples/train_100m.py [--steps 150]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.train import run
from repro.models import init_params


def config_100m():
    base = get_config("qwen2-0.5b")
    return dataclasses.replace(
        base, name="qwen2-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=2, d_head=64, d_ff=2048, vocab=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--ckpt-dir", default="/tmp/ckpt_100m")
    args = ap.parse_args()
    cfg = config_100m()
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        jax.eval_shape(lambda k: init_params(k, cfg),
                       jax.random.PRNGKey(0))))
    print(f"[100m] param count: {n/1e6:.1f}M")
    import repro.launch.train as T
    import repro.configs as C
    orig = C.get_smoke_config
    C.get_smoke_config = lambda a: cfg          # route the driver to 100M
    T.get_smoke_config = lambda a: cfg
    try:
        _, _, losses = run("qwen2-100m", smoke=True, steps=args.steps,
                           batch=2, seq=128, lr=6e-4,
                           ckpt_dir=args.ckpt_dir, ckpt_every=50,
                           log_every=10)
    finally:
        C.get_smoke_config = orig
        T.get_smoke_config = orig
    print(f"[100m] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
