"""Posit-quantized serving demo: quantize a model's weights to posit
words, stand up the continuous-batching engine with a paged p16e1
KV-cache, and replay a synthetic traffic trace — then show the two
claims that make it interesting: the batched decode is bit-identical
to serving each request alone, and the posit storage is >= 2x smaller.

    PYTHONPATH=src python examples/serve_posit.py
"""
import jax
import numpy as np

from repro.configs import get_tiny_config
from repro.models import init_params
from repro.serving import (Engine, QuantConfig, TrafficConfig,
                           param_bytes, quantize_params, replay,
                           synth_trace, weight_golden_zone)

cfg = get_tiny_config("qwen2-0.5b", policy="f32")
params = init_params(jax.random.PRNGKey(0), cfg)

# --- 1. quantize the weights to p16e1 ------------------------------------
# Per-channel pow2 equilibration first (exactly invertible in f32), then
# round each weight to the nearest posit — the scales push the channel
# maxima into the golden zone where p16e1 keeps its finest spacing.
qp = quantize_params(params, QuantConfig(fmt="p16e1"))
pb = param_bytes(qp)
print(f"weights: {pb['q_f32_bytes']:,} f32 bytes -> "
      f"{pb['word_bytes']:,} posit bytes "
      f"({pb['q_f32_bytes'] / pb['word_bytes']:.1f}x smaller), "
      f"golden-zone occupancy {weight_golden_zone(qp):.2f}")

# --- 2. serve a synthetic trace ------------------------------------------
# Continuous batching: requests arrive over time, are admitted into free
# rows as pages permit, decode together in one fixed-width jitted step,
# and retire independently (eos / max_new).  The KV-cache lives in paged
# p16e1 pools — same 2x saving as the weights.
trace = synth_trace(TrafficConfig(n_requests=6, mean_plen=8, mean_new=5,
                                  vocab=cfg.vocab, seed=0))
eng = Engine(qp, cfg, max_batch=3, page_size=16, max_seq=64,
             kv_fmt="p16e1")
rep = replay(eng, trace)
kb = eng.kv_bytes()
print(f"replayed {rep['requests']} requests / {rep['tokens']} tokens in "
      f"{rep['steps']} steps: {rep['tok_s']:.0f} tok/s, "
      f"mean occupancy {rep['occupancy']:.2f}")
print(f"KV pool: {kb['f32_bytes']:,} f32-equiv bytes -> {kb['bytes']:,} "
      f"stored ({kb['f32_bytes'] / kb['bytes']:.1f}x smaller)")

# --- 3. batched == sequential, bit for bit -------------------------------
# The engine decodes every inflight request in ONE jitted program at a
# fixed batch width; rows cannot see each other.  So the same requests
# served one at a time (max_inflight=1) produce the same tokens.
reqs = [type(r)(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
        for r in trace]
seq = Engine(qp, cfg, max_batch=3, page_size=16, max_seq=64,
             kv_fmt="p16e1", max_inflight=1).run(reqs)
assert all(np.array_equal(rep["outputs"][k], seq[k]) for k in seq)
print("batched decode is bit-identical to sequential decode")
for rid in sorted(rep["outputs"]):
    print(f"  request {rid}: {rep['outputs'][rid].tolist()}")
