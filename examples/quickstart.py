"""Quickstart: Posit(32,2) arithmetic, the paper's linear-algebra stack,
and the golden-zone accuracy effect — in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.lapack.error_eval import backward_error_study

# --- 1. posit scalars/vectors -------------------------------------------
x = np.array([1.0, 3.141592653589793, -0.001, 1e6])
px = P.from_float64(x)                      # int32 posit words
print("posit32 words:", [hex(np.uint32(w)) for w in np.asarray(px)])
print("decoded:      ", np.asarray(P.to_float64(px)))
print("rel eps:      ", np.asarray(P.rounding_eps(x)),
      " (binary32 eps ~ 6e-8; inside the golden zone posit is finer)")

s = P.add(px, px)
print("x + x:        ", np.asarray(P.to_float64(s)))

# --- 2. posit GEMM (the paper's accelerator op) --------------------------
rng = np.random.default_rng(0)
a = P.from_float64(rng.standard_normal((64, 64)))
b = P.from_float64(rng.standard_normal((64, 64)))
c_quire = rgemm(a, b, backend="xla_quire")       # tile-accumulated
c_faith = rgemm(a, b, backend="faithful")        # per-MAC rounding (paper PE)
c_pallas = rgemm(a, b, backend="pallas_split3")  # TPU kernel (interpret)
va = np.asarray(P.to_float64(a)); vb = np.asarray(P.to_float64(b))
truth = va @ vb
for name, c in [("quire", c_quire), ("faithful", c_faith),
                ("pallas", c_pallas)]:
    err = np.abs(np.asarray(P.to_float64(c)) - truth).max()
    print(f"GEMM[{name:8s}] max abs err vs f64: {err:.3e}")

# --- 3. the paper's headline: golden-zone accuracy ----------------------
for sigma in (1.0, 1e6):
    r = backward_error_study(64, sigma, "lu", nb=16,
                             gemm_backend="faithful")
    print(f"LU sigma={sigma:g}: posit beats binary32 by "
          f"{r.digits:+.2f} digits of backward error")
