"""Quickstart: posit arithmetic, the paper's linear-algebra stack, the
golden-zone accuracy effect, choosing a posit format, quire-exact
least squares, observability, and posit-quantized serving.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.lapack.error_eval import backward_error_study

# --- 1. posit scalars/vectors -------------------------------------------
x = np.array([1.0, 3.141592653589793, -0.001, 1e6])
px = P.from_float64(x)                      # int32 posit words
print("posit32 words:", [hex(np.uint32(w)) for w in np.asarray(px)])
print("decoded:      ", np.asarray(P.to_float64(px)))
print("rel eps:      ", np.asarray(P.rounding_eps(x)),
      " (binary32 eps ~ 6e-8; inside the golden zone posit is finer)")

s = P.add(px, px)
print("x + x:        ", np.asarray(P.to_float64(s)))

# --- 2. posit GEMM (the paper's accelerator op) --------------------------
rng = np.random.default_rng(0)
a = P.from_float64(rng.standard_normal((64, 64)))
b = P.from_float64(rng.standard_normal((64, 64)))
c_quire = rgemm(a, b, backend="xla_quire")       # tile-accumulated
c_faith = rgemm(a, b, backend="faithful")        # per-MAC rounding (paper PE)
c_pallas = rgemm(a, b, backend="pallas_split3")  # TPU kernel (interpret)
va = np.asarray(P.to_float64(a))
vb = np.asarray(P.to_float64(b))
truth = va @ vb
for name, c in [("quire", c_quire), ("faithful", c_faith),
                ("pallas", c_pallas)]:
    err = np.abs(np.asarray(P.to_float64(c)) - truth).max()
    print(f"GEMM[{name:8s}] max abs err vs f64: {err:.3e}")

# --- 3. the paper's headline: golden-zone accuracy ----------------------
for sigma in (1.0, 1e6):
    r = backward_error_study(64, sigma, "lu", nb=16,
                             gemm_backend="faithful")
    print(f"LU sigma={sigma:g}: posit beats binary32 by "
          f"{r.digits:+.2f} digits of backward error")

# --- 4. choosing a format ------------------------------------------------
# The whole stack is format-parametric: pass fmt= to rgemm, rpotrf/rgetrf,
# rpotrs/rgetrs, rgesv_ir and friends (DESIGN.md §8).  Rules of thumb:
#   * p32e2 — the paper's format and the default: 27-bit fractions near 1,
#     beats binary32 inside the golden zone (|x| in ~[1e-3, 1e3]).
#     Use it whenever accuracy is the point.
#   * p16e1 — half the memory, 4x smaller quire (4 limbs vs 16): the
#     FACTORIZATION format for mixed-precision solves (refine.rgesv_mp
#     factorizes in p16e1 and refines with p32e2 quire residuals to the
#     same backward error as a full p32e2 solve — the HPL-AI play).
#     Standalone, expect ~eps 2^-12 accuracy in the golden zone.
#   * p8e2  — 8-bit storage with dynamic range out to 2^24: quantized
#     storage / compression experiments, not linear algebra.
# Same matrix, three formats — watch the accuracy/width trade:
from repro.core.formats import P16E1, P8E2, P32E2
for fmt in (P32E2, P16E1, P8E2):
    r = backward_error_study(64, 1.0, "lu", nb=16,
                             gemm_backend="xla_quire", fmt=fmt)
    print(f"LU in {fmt.name}: backward error {r.e_posit:.2e} "
          f"({r.digits:+.2f} digits vs binary32)")

# --- 5. least squares (over-determined systems) --------------------------
# Householder QR (lapack/qr.py): rgels solves min ||A x - b|| via
# x = R^{-1} (Q^T b); rgels_ir refines the solution with quire-exact
# residuals and semi-normal-equations corrections until it sits on the
# TRUE least-squares optimum of the posit-held problem (for an
# over-determined system, quantizing (A, b) to posit words leaves a
# residual floor no solver can beat — rgels_ir reaches it; rgels_mp
# factorizes in cheap p16e1 and lands on the same floor).
from repro.lapack import rgels, rgels_ir
from repro.lapack.refine import pair_to_float64

m, n = 96, 64
a64 = rng.standard_normal((m, n))
b64 = a64 @ np.full(n, 1.0 / np.sqrt(n))
ap, bp = P.from_float64(a64), P.from_float64(b64)
aq, bq = np.asarray(P.to_float64(ap)), np.asarray(P.to_float64(bp))
x_plain, _ = rgels(ap, bp, nb=16)
(x_hi, x_lo), _ = rgels_ir(ap, bp, iters=3, nb=16)
for name, x in [("rgels    ", np.asarray(P.to_float64(x_plain))),
                ("rgels_ir ", np.asarray(pair_to_float64(x_hi, x_lo)))]:
    e = np.linalg.norm(bq - aq @ x) / np.linalg.norm(bq)
    print(f"LS {name} m={m} n={n}: backward error {e:.2e}")
e_opt = np.linalg.norm(bq - aq @ np.linalg.lstsq(aq, bq, rcond=None)[0]
                       ) / np.linalg.norm(bq)
print(f"LS optimum (f64 lstsq on the same posit-held data): {e_opt:.2e}")

# --- 6. observability (positscope, DESIGN.md §10) ------------------------
# Open a scope and every instrumented call underneath records: golden-zone
# occupancy (the fraction of words where posit keeps its maximal fraction
# bits — the mechanism behind §3's sigma effect), per-sweep refinement
# convergence, and span timings.  Closed scope => zero cost: the lowered
# programs are byte-identical (pinned in tests/test_obs.py).
from repro import obs
from repro.lapack import rgesv_ir

bp_sq = P.from_float64(a64[:n, 0])
with obs.scoped() as mtr:
    rgesv_ir(P.from_float64(a64[:n, :n]), bp_sq, iters=3, nb=16)
d = mtr.to_dict()
gz = d["gauges"]["rgetrf.last_panel.golden_zone"]
print(f"observed: A golden-zone {gz:.2f}, "
      f"{int(d['counters']['ir.sweeps'])} IR sweeps, "
      f"last ||r|| {d['series']['ir.sweep'][-1]['r_norm']:.1e}, "
      f"{d['spans']} spans  (mtr.save_chrome_trace(...) -> Perfetto)")

# --- 7. posit-quantized serving (DESIGN.md §12) --------------------------
# The LLM side of the same trade: quantize weights to p16e1 words with
# per-channel pow2 equilibration (exactly invertible; pushes channels
# into the golden zone), then decode through a continuous-batching
# engine whose KV-cache lives in paged posit pools — half the HBM of
# f32, and the batched decode is bit-identical to serving each request
# alone (examples/serve_posit.py runs the full demo).
import jax
from repro.configs import get_tiny_config
from repro.models import init_params
from repro.serving import QuantConfig, param_bytes, quantize_params

cfg = get_tiny_config("qwen2-0.5b", policy="f32")
qp = quantize_params(init_params(jax.random.PRNGKey(0), cfg),
                     QuantConfig(fmt="p16e1"))
pb = param_bytes(qp)
print(f"qwen2 weights as p16e1: "
      f"{pb['q_f32_bytes'] / pb['word_bytes']:.1f}x smaller")
