"""Train a small LM end-to-end with posit numeric policies.

Compares three numeric policies on the same model/data:
  bf16        — baseline
  posit32     — paper-faithful QAT (weights+activations on the p32 lattice)
  bf16_opt16  — posit16-compressed optimizer moments (golden-zone
                re-centering; what makes llama3-405b fit 512 chips)

    PYTHONPATH=src python examples/posit_training.py
"""
from repro.launch.train import run

for policy in ("bf16", "posit32", "bf16_opt16"):
    print(f"\n=== policy = {policy} ===")
    _, _, losses = run("qwen2-0.5b", smoke=True, steps=20, batch=4,
                       seq=64, lr=1e-3, policy=policy, log_every=10)
    print(f"policy {policy}: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
