"""benchmarks/merge_bench.py — the bench reporting pipeline is itself
tier-1-gated: merge semantics, markdown table, and the warn-only
baseline-diff mode, all on synthetic BENCH_*.json fixtures."""
import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                       / "benchmarks"))
import merge_bench  # noqa: E402


def _payload(bench, rows, python="3.10"):
    return {"meta": {"bench": bench, "python": python, "jax": "0.4.37",
                     "platform": "test"},
            "results": rows}


@pytest.fixture
def bench_files(tmp_path):
    a = _payload("bench_alpha", [
        {"name": "gemm", "config": "n=64", "t_old_ms": 10.0,
         "t_new_ms": 5.0, "speedup": 2.0, "identical": True},
        {"name": "acc", "config": "sigma=1", "digits_vs_b32": 0.8},
    ])
    b = _payload("bench_beta", [
        {"name": "dist", "config": "n=96", "t_single_ms": 8.0,
         "t_dist_ms": 4.0, "speedup": 2.0, "identical": False,
         "devices": 4},
        {"name": "mixed", "config": "n=48", "digits_lost": 0.01},
    ])
    pa = tmp_path / "BENCH_alpha.json"
    pb = tmp_path / "BENCH_beta.json"
    pa.write_text(json.dumps(a))
    pb.write_text(json.dumps(b))
    return tmp_path, pa, pb


def test_merge_and_markdown(bench_files, capsys):
    tmp, pa, pb = bench_files
    out = tmp / "BENCH_summary.json"
    merge_bench.main([str(pa), str(pb), "--out", str(out), "--markdown"])
    summary = json.loads(out.read_text())
    assert sorted(summary["benches"]) == ["bench_alpha", "bench_beta"]
    assert summary["merged_from"] == sorted([str(pa), str(pb)])
    md = capsys.readouterr().out
    assert "| bench_alpha | gemm | n=64 | 10.0 | 5.0 | 2.00x | ok |" in md
    assert "+0.80 digits vs b32" in md
    assert "!!" in md                        # failed gate marker survives
    assert "n=96 x4dev" in md                # devices fold into config
    assert "vs base" not in md               # no baseline -> no column


def test_merge_skips_prior_summary(bench_files):
    tmp, pa, pb = bench_files
    out = tmp / "BENCH_summary.json"
    merge_bench.main([str(pa), str(pb), "--out", str(out)])
    # re-merge with the old summary matching the documented glob: the
    # merged_from payload must be recognized and skipped, not nested
    merge_bench.main([str(pa), str(pb), str(out), "--out", str(out)])
    summary = json.loads(out.read_text())
    assert sorted(summary["benches"]) == ["bench_alpha", "bench_beta"]


def test_baseline_deltas_ratio_and_missing_rows(bench_files):
    tmp, pa, pb = bench_files
    benches = merge_bench.load([str(pa), str(pb)])
    base_dir = tmp / "base"
    base_dir.mkdir()
    # baseline: gemm was 2x slower (10ms vs fresh 5ms), dist row missing
    (base_dir / "BENCH_alpha.json").write_text(json.dumps(_payload(
        "bench_alpha", [{"name": "gemm", "config": "n=64",
                         "t_old_ms": 20.0, "t_new_ms": 10.0}])))
    deltas = merge_bench.baseline_deltas(
        benches, merge_bench.load_baseline(str(base_dir)))
    # matched row gets the ratio; the timing row with no baseline
    # counterpart is still emitted, with a None delta (new-bench case)
    assert deltas == {("bench_alpha", ("gemm", "n=64", None)): 2.0,
                      ("bench_beta", ("dist", "n=96", 4)): None}


def test_new_bench_without_baseline_row_emits_null_delta(bench_files):
    """The BENCH_ft.json bootstrap case: a brand-new bench whose file has
    NO committed baseline at all must ride through --baseline mode with
    its rows in baseline_diff (null delta) and a '-' markdown cell —
    never a crash, never a silent skip."""
    tmp, pa, pb = bench_files
    out = tmp / "BENCH_summary.json"
    base_dir = tmp / "base"
    base_dir.mkdir()
    # baseline only knows bench_alpha; bench_beta is "new"
    (base_dir / "BENCH_alpha.json").write_text(json.dumps(_payload(
        "bench_alpha", [{"name": "gemm", "config": "n=64",
                         "t_new_ms": 5.0}])))
    merge_bench.main([str(pa), str(pb), "--out", str(out),
                      "--baseline", str(base_dir)])
    summary = json.loads(out.read_text())
    diff = {(d["bench"], d["name"]): d["speed_vs_baseline"]
            for d in summary["baseline_diff"]}
    assert diff == {("bench_alpha", "gemm"): 1.0,
                    ("bench_beta", "dist"): None}


def test_corrupt_baseline_file_is_skipped(bench_files, capsys):
    """A truncated committed baseline must not fail the merge: the bad
    file is skipped (warning to stderr) and its rows show no delta."""
    tmp, pa, pb = bench_files
    out = tmp / "BENCH_summary.json"
    base_dir = tmp / "base"
    base_dir.mkdir()
    (base_dir / "BENCH_alpha.json").write_text('{"meta": {"bench":')
    (base_dir / "BENCH_beta.json").write_text(json.dumps(_payload(
        "bench_beta", [{"name": "dist", "config": "n=96",
                        "t_dist_ms": 8.0, "devices": 4}])))
    merge_bench.main([str(pa), str(pb), "--out", str(out),
                      "--baseline", str(base_dir)])  # must not raise
    assert "skipping unreadable baseline" in capsys.readouterr().err
    summary = json.loads(out.read_text())
    diff = {(d["bench"], d["name"]): d["speed_vs_baseline"]
            for d in summary["baseline_diff"]}
    assert diff == {("bench_alpha", "gemm"): None,
                    ("bench_beta", "dist"): 2.0}


def test_baseline_markdown_column_and_warn_marker(bench_files):
    tmp, pa, pb = bench_files
    out = tmp / "BENCH_summary.json"
    base_dir = tmp / "base"
    base_dir.mkdir()
    # gemm: baseline 4x FASTER than fresh -> ratio 0.4 -> "(slow)" warn;
    # beta's dist row: baseline matches fresh -> 1.00x, no warn
    (base_dir / "BENCH_alpha.json").write_text(json.dumps(_payload(
        "bench_alpha", [{"name": "gemm", "config": "n=64",
                         "t_new_ms": 2.0}])))
    (base_dir / "BENCH_beta.json").write_text(json.dumps(_payload(
        "bench_beta", [{"name": "dist", "config": "n=96",
                        "t_dist_ms": 4.0, "devices": 4}])))
    merge_bench.main([str(pa), str(pb), "--out", str(out), "--markdown",
                      "--baseline", str(base_dir)])
    summary = json.loads(out.read_text())
    diff = {(d["bench"], d["name"], d["devices"]): d["speed_vs_baseline"]
            for d in summary["baseline_diff"]}
    # devices is part of the emitted record so bench_dist's per-device
    # rows (same name+config, different device count) stay tellable
    assert diff == {("bench_alpha", "gemm", None): 0.4,
                    ("bench_beta", "dist", 4): 1.0}


def test_baseline_mode_is_warn_only(bench_files, capsys):
    """A catastrophically slower run must still exit 0 (warn-only)."""
    tmp, pa, pb = bench_files
    out = tmp / "BENCH_summary.json"
    base_dir = tmp / "base"
    base_dir.mkdir()
    (base_dir / "BENCH_alpha.json").write_text(json.dumps(_payload(
        "bench_alpha", [{"name": "gemm", "config": "n=64",
                         "t_new_ms": 0.001}])))
    merge_bench.main([str(pa), str(pb), "--out", str(out), "--markdown",
                      "--baseline", str(base_dir)])  # must not raise
    md = capsys.readouterr().out
    assert "vs base" in md
    assert "(slow)" in md
    assert "0.00x (slow)" in md
    # rows with no baseline counterpart render "-", never crash
    assert "| - |" in md


def test_serving_tok_s_column(tmp_path, capsys):
    """Serving rows (bench_serve) carry tok_s; the markdown metric cell
    must surface it as 'N tok/s' alongside the latency ratio."""
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(_payload("bench_serve", [
        {"name": "replay", "config": "fmt=p16e1 b=4", "t_old_ms": 40.0,
         "t_new_ms": 20.0, "speedup": 2.0, "tok_s": 123.4,
         "identical": True},
        {"name": "replay", "config": "fmt=p8e2 b=4", "t_new_ms": 18.0,
         "tok_s": 97.6},
    ])))
    out = tmp_path / "BENCH_summary.json"
    merge_bench.main([str(p), "--out", str(out), "--markdown"])
    md = capsys.readouterr().out
    assert "2.00x, 123 tok/s" in md       # appended after the ratio
    assert "| 98 tok/s |" in md           # alone when no speedup field
