"""Pallas posit GEMM kernel vs the pure-jnp oracles (interpret mode).

Sweeps shapes / block sizes / magnitude regimes (the paper's sigma axis)
and asserts against kernels/ref.py.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.kernels.posit_gemm import decode_split_f32, posit_gemm_f32


def make_inputs(m, k, n, sigma, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)) * sigma
    b = rng.standard_normal((k, n)) * sigma
    return (jnp.asarray(P.from_float64(a)), jnp.asarray(P.from_float64(b)))


def test_decode_split_exact():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(20000) * np.exp(rng.uniform(-18, 18, 20000))
    p = P.from_float64(x)
    v = np.asarray(P.to_float64(p))
    hi, lo = decode_split_f32(jnp.asarray(p))
    rec = np.asarray(hi, np.float64) + np.asarray(lo, np.float64)
    assert np.array_equal(rec, v)


def test_decode_split_specials():
    pats = np.array([0, P.P32E2.nar_pattern if hasattr(P, "P32E2") else
                     -(1 << 31)], np.int32)
    hi, lo = decode_split_f32(jnp.asarray(pats))
    assert float(hi[0]) == 0.0 and float(lo[0]) == 0.0
    assert np.isnan(np.asarray(hi)[1])


@pytest.mark.parametrize("shape", [(128, 128, 128), (128, 256, 128),
                                   (256, 128, 384)])
@pytest.mark.parametrize("sigma", [1.0, 1e-2, 1e4])
def test_kernel_matches_quire_semantics(shape, sigma):
    m, k, n = shape
    ap, bp = make_inputs(m, k, n, sigma)
    av = np.asarray(P.to_float64(ap))
    bv = np.asarray(P.to_float64(bp))
    truth = av @ bv
    out = np.asarray(posit_gemm_f32(ap, bp), np.float64)
    scale = np.outer(np.linalg.norm(av, axis=1), np.linalg.norm(bv, axis=0))
    err = np.abs(out - truth) / np.maximum(scale, 1e-300)
    # f32 accumulation with exact 28-bit inputs: error ~ sqrt(K) * 2^-24
    assert err.max() < np.sqrt(k) * 8e-8, err.max()


@pytest.mark.parametrize("mode", ["split3", "split3_comp"])
def test_kernel_block_sweep(mode):
    ap, bp = make_inputs(64, 192, 64, 1.0)
    ref_out = np.asarray(posit_gemm_f32(ap, bp, bm=64, bn=64, bk=64,
                                        mode=mode))
    for bm, bn, bk in [(32, 32, 96), (64, 64, 192), (32, 64, 64)]:
        out = np.asarray(posit_gemm_f32(ap, bp, bm=bm, bn=bn, bk=bk,
                                        mode=mode))
        av = np.asarray(P.to_float64(ap))
        bv = np.asarray(P.to_float64(bp))
        sc = np.outer(np.linalg.norm(av, axis=1),
                      np.linalg.norm(bv, axis=0))
        assert (np.abs(out - ref_out) / np.maximum(sc, 1e-300)).max() < 1e-6


def test_compensated_beats_plain_on_long_k():
    ap, bp = make_inputs(8, 4096, 8, 1.0, seed=1)
    av = np.asarray(P.to_float64(ap))
    bv = np.asarray(P.to_float64(bp))
    truth = av @ bv
    plain = np.asarray(posit_gemm_f32(ap, bp, bm=8, bn=8, bk=128,
                                      mode="split3"), np.float64)
    comp = np.asarray(posit_gemm_f32(ap, bp, bm=8, bn=8, bk=128,
                                     mode="split3_comp"), np.float64)
    e_plain = np.abs(plain - truth).max()
    e_comp = np.abs(comp - truth).max()
    assert e_comp <= e_plain * 1.01


def test_rgemm_faithful_chain_is_bit_exact():
    ap, bp = make_inputs(8, 8, 8, 1.0)
    got = np.asarray(rgemm(ap, bp, backend="faithful"))
    acc = np.zeros((8, 8), np.int32)
    for kk in range(8):
        prod = np.asarray(P.mul(np.asarray(ap)[:, kk][:, None],
                                np.asarray(bp)[kk, :][None, :]))
        acc = np.asarray(P.add(acc, prod))
    assert np.array_equal(got, acc)


def test_rgemm_alpha_beta_and_transposes():
    rng = np.random.default_rng(2)
    a = rng.standard_normal((16, 24))
    b = rng.standard_normal((24, 16))
    c = rng.standard_normal((16, 16))
    ap, bp = P.from_float64(a), P.from_float64(b)
    cp = P.from_float64(c)
    out = rgemm(ap, bp, cp, alpha=2.0, beta=-0.5, backend="xla_quire")
    got = np.asarray(P.to_float64(out))
    av = np.asarray(P.to_float64(ap))
    bv = np.asarray(P.to_float64(bp))
    cv = np.asarray(P.to_float64(cp))
    want = 2.0 * av @ bv - 0.5 * cv
    assert np.abs(got - want).max() / np.abs(want).max() < 1e-6
    # transposes reduce to the plain product
    t1 = np.asarray(rgemm(ap.T, bp, trans_a=True, backend="xla_quire"))
    t2 = np.asarray(rgemm(ap, bp.T, trans_b=True, backend="xla_quire"))
    base = np.asarray(rgemm(ap, bp, backend="xla_quire"))
    assert np.array_equal(t1, base) and np.array_equal(t2, base)


def test_quire_vs_faithful_accuracy():
    """Beyond-paper claim: single-rounding (quire) GEMM is at least as
    accurate as the paper's per-MAC-rounding chain."""
    ap, bp = make_inputs(32, 256, 32, 1.0, seed=3)
    av = np.asarray(P.to_float64(ap))
    bv = np.asarray(P.to_float64(bp))
    truth = av @ bv
    q = np.asarray(P.to_float64(rgemm(ap, bp, backend="xla_quire")))
    f = np.asarray(P.to_float64(rgemm(ap, bp, backend="faithful")))
    sc = np.abs(truth) + 1e-300
    assert np.median(np.abs(q - truth) / sc) <= \
        np.median(np.abs(f - truth) / sc)
