"""Launch-layer unit tests: HLO collective parser, sharding rules, mesh
construction (no 512-device flag needed — pure logic + 1-device paths)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import dp_axes, make_smoke_mesh
from repro.launch.sharding import _spec_for_axes
from repro.models.common import Axes


def test_collective_bytes_parser():
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[4,256]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[8]{0}, s32[8]{0}) reduce-scatter(%a, %b)
  %a2a = s16[1024]{0} all-to-all(%c)
  %cp = u8[64]{0} collective-permute(%d)
  %not_a_collective = f32[999]{0} add(%e, %f)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 128 * 4
    assert got["all-gather"] == 4 * 256 * 2
    assert got["reduce-scatter"] == 8 * 4 + 8 * 4
    assert got["all-to-all"] == 1024 * 2
    assert got["collective-permute"] == 64
    assert "add" not in got


def test_spec_for_axes_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeShape(dict):
        pass
    # TP: mlp -> model
    s = _spec_for_axes(Axes((None, "mlp")), (64, 128), mesh, fsdp=False)
    assert s == P(None, "model")
    # stacked leading dim gets None
    s = _spec_for_axes(Axes((None, "mlp")), (12, 64, 128), mesh, fsdp=False)
    assert s == P(None, None, "model")
    # duplicate mesh axes: first wins (EP over mlp)
    s = _spec_for_axes(Axes(("experts", None, "mlp")), (8, 64, 128), mesh,
                       fsdp=False)
    assert s == P("model", None, None)
    # non-divisible dims are dropped
    mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    s = _spec_for_axes(Axes(("heads",)), (7,), mesh16, fsdp=False)
    # 7 % 1 == 0 on the 1-device mesh, so it keeps the axis; use shape 0-safe
    assert s in (P("model"), P(None))


def test_mesh_helpers():
    m = make_smoke_mesh()
    assert dp_axes(m) == ("data",)
    assert m.shape["model"] == 1


def test_dist_context_plumbing():
    from repro.launch.context import DistContext, current, use
    assert current() is None
    m = make_smoke_mesh()
    ctx = DistContext(mesh=m, dp=("data",))
    with use(ctx):
        assert current() is ctx
    assert current() is None


def test_ep_moe_matches_local_on_one_device():
    """EP shard_map path on a 1x1 mesh must agree with the local path
    (same routing, no drops at capacity_factor=2 with E=4)."""
    from repro.configs import get_smoke_config
    from repro.launch.context import DistContext, use
    from repro.models import ffn as ffn_mod
    from repro.models import init_params

    cfg = get_smoke_config("granite-moe-1b-a400m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    moe_params = jax.tree.map(lambda a: a[0], params["layers"][0])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    pol = cfg.get_policy()
    y_local, aux_l = ffn_mod.moe_apply_local(moe_params, x, cfg, pol,
                                             jnp.bfloat16)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = DistContext(mesh=mesh, dp=("data",), seq=None)
    y_ep, aux_e = ffn_mod.moe_apply_ep(moe_params, x, cfg, pol,
                                       jnp.bfloat16, ctx,
                                       capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y_local, np.float32),
                               np.asarray(y_ep, np.float32),
                               rtol=0.15, atol=0.05)
    np.testing.assert_allclose(float(aux_l), float(aux_e), rtol=1e-3)
