"""Quire subsystem vs the exact rational oracle + refinement acceptance.

The quire is EXACT by construction, so every test here is bit-identity
against fractions.Fraction arithmetic (posit_oracle), not a tolerance.
"""
from fractions import Fraction

import jax.numpy as jnp
import numpy as np

import posit_oracle as oracle
from repro.core import posit as P
from repro.core.formats import P16E1, P32E2
from repro import quire as Q
from repro.kernels.ops import rgemm
from repro.lapack.blas import rtrsv_lower, rtrsv_lower_quire
from repro.lapack import refine
from repro.lapack.error_eval import refinement_study


def _rand_posit_words(rng, shape, fmt, lo_exp=-20, hi_exp=20):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo_exp, hi_exp,
                                                         shape))
    return np.asarray(P.from_float64(jnp.asarray(x), fmt))


def _oracle_val(p, fmt):
    v = oracle.decode(int(p), fmt.nbits, fmt.es)
    return v if v is not None else None


# --------------------------------------------------------------------------
# fdp / quire_dot bit-exactness
# --------------------------------------------------------------------------

def test_fdp_matches_rational_oracle():
    rng = np.random.default_rng(0)
    for fmt in (P32E2, P16E1):
        for trial in range(8):
            k = 25
            # mixed magnitudes: stress alignment across the whole quire
            ap = _rand_posit_words(rng, (k,), fmt, -40, 40)
            bp = _rand_posit_words(rng, (k,), fmt,
                                   *((-40, 40) if trial % 2 else (0, 1)))
            got = int(np.asarray(Q.fdp(jnp.asarray(ap), jnp.asarray(bp),
                                       fmt)))
            exact = sum((_oracle_val(x, fmt) * _oracle_val(y, fmt)
                         for x, y in zip(ap, bp)), Fraction(0))
            want = oracle.encode(exact, fmt.nbits, fmt.es)
            assert got == want, (fmt.name, trial, got, want)


def test_quire_dot_init_and_negate():
    rng = np.random.default_rng(1)
    fmt = P32E2
    k = 19
    ap = _rand_posit_words(rng, (k,), fmt)
    bp = _rand_posit_words(rng, (k,), fmt)
    cp = _rand_posit_words(rng, (), fmt)
    got = int(np.asarray(Q.quire_dot(jnp.asarray(ap), jnp.asarray(bp), fmt,
                                     init_p=jnp.asarray(cp), negate=True)))
    exact = _oracle_val(cp, fmt) - sum(
        (_oracle_val(x, fmt) * _oracle_val(y, fmt) for x, y in zip(ap, bp)),
        Fraction(0))
    assert got == oracle.encode(exact, 32, 2)


def test_quire_specials_and_saturation():
    one = np.array([0x40000000], np.uint32).view(np.int32)
    nar = np.array([P32E2.nar_pattern], np.int32)
    maxp = np.array([P32E2.maxpos_pattern], np.int32)
    minp = np.array([P32E2.minpos_pattern], np.int32)

    # exact cancellation -> true zero
    q = Q.quire_from_posit(jnp.asarray(one))
    q = Q.qadd_posit(q, jnp.asarray(one), negate=True)
    assert int(np.asarray(Q.q_to_posit(q))[0]) == 0

    # NaR poisons the accumulator
    qn = Q.qma(Q.quire_zero((1,)), jnp.asarray(nar), jnp.asarray(one))
    assert int(np.asarray(Q.q_to_posit(qn))[0]) == P32E2.nar_pattern

    # sums beyond maxpos saturate (posits never overflow to NaR)
    qs = Q.quire_zero((1,))
    for _ in range(3):
        qs = Q.qma(qs, jnp.asarray(maxp), jnp.asarray(maxp))
    assert int(np.asarray(Q.q_to_posit(qs))[0]) == P32E2.maxpos_pattern

    # minpos^2 (the quire LSB) rounds back up to minpos, not to zero
    qm = Q.qma(Q.quire_zero((1,)), jnp.asarray(minp), jnp.asarray(minp))
    assert int(np.asarray(Q.q_to_posit(qm))[0]) == 1

    # qneg is exact
    q2 = Q.qma(Q.quire_zero((1,)), jnp.asarray(one), jnp.asarray(one))
    assert int(np.asarray(Q.q_to_posit(Q.qneg(q2)))[0]) == \
        int(np.asarray(P.neg_(one))[0])


def test_renorm_and_limbs32_roundtrip():
    rng = np.random.default_rng(2)
    ap = _rand_posit_words(rng, (64,), P32E2, -30, 30)
    bp = _rand_posit_words(rng, (64,), P32E2, -30, 30)
    q = Q.quire_zero((64,))
    q = Q.qma(q, jnp.asarray(ap), jnp.asarray(bp))
    ref = np.asarray(Q.q_to_posit(q))
    # renorm preserves the value
    assert np.array_equal(np.asarray(Q.q_to_posit(Q.q_renorm(q))), ref)
    # int32 plane layout round-trips
    planes, nar = Q.to_limbs32(q)
    assert planes.dtype == jnp.int32
    q2 = Q.from_limbs32(planes, nar)
    assert np.array_equal(np.asarray(q2.limbs), np.asarray(q.limbs))


# --------------------------------------------------------------------------
# rgemm backend="quire_exact": bit-identical to exact-dot-then-round
# --------------------------------------------------------------------------

def test_rgemm_quire_exact_matches_oracle():
    rng = np.random.default_rng(3)
    # non-multiples of the 128 block, scales spanning 2^-20 .. 2^20
    for (m, k, n) in ((17, 23, 9), (8, 40, 13), (33, 19, 21)):
        ap = _rand_posit_words(rng, (m, k), P32E2, -20, 20)
        bp = _rand_posit_words(rng, (k, n), P32E2, -20, 20)
        got = np.asarray(rgemm(jnp.asarray(ap), jnp.asarray(bp),
                               backend="quire_exact"))
        va = [[_oracle_val(x, P32E2) for x in row] for row in ap]
        vb = [[_oracle_val(x, P32E2) for x in row] for row in bp]
        for i in range(m):
            for j in range(n):
                exact = sum((va[i][l] * vb[l][j] for l in range(k)),
                            Fraction(0))
                want = oracle.encode(exact, 32, 2)
                assert int(got[i, j]) == want, ((m, k, n), i, j)


def test_rgemm_quire_exact_alpha_beta_fused():
    """alpha=-1/beta=1 (the trailing-update shape) stays single-rounding."""
    rng = np.random.default_rng(4)
    m, k, n = 11, 14, 7
    ap = _rand_posit_words(rng, (m, k), P32E2, -4, 4)
    bp = _rand_posit_words(rng, (k, n), P32E2, -4, 4)
    cp = _rand_posit_words(rng, (m, n), P32E2, -4, 4)
    got = np.asarray(rgemm(jnp.asarray(ap), jnp.asarray(bp), jnp.asarray(cp),
                           alpha=-1.0, beta=1.0, backend="quire_exact"))
    va = [[_oracle_val(x, P32E2) for x in row] for row in ap]
    vb = [[_oracle_val(x, P32E2) for x in row] for row in bp]
    for i in range(m):
        for j in range(n):
            exact = _oracle_val(cp[i, j], P32E2) - sum(
                (va[i][l] * vb[l][j] for l in range(k)), Fraction(0))
            assert int(got[i, j]) == oracle.encode(exact, 32, 2), (i, j)


# --------------------------------------------------------------------------
# quire substitutions + iterative refinement (acceptance: >= 2 digits)
# --------------------------------------------------------------------------

def test_rtrsv_quire_no_worse_than_plain():
    rng = np.random.default_rng(5)
    n = 40
    l64 = np.tril(rng.standard_normal((n, n))) + 4 * np.eye(n)
    x64 = rng.standard_normal(n)
    b64 = l64 @ x64
    lp = P.from_float64(jnp.asarray(l64))
    bp = P.from_float64(jnp.asarray(b64))
    eq = np.abs(np.asarray(P.to_float64(rtrsv_lower_quire(lp, bp))) - x64)
    ep = np.abs(np.asarray(P.to_float64(rtrsv_lower(lp, bp))) - x64)
    assert eq.max() <= ep.max() * 1.5   # typically 2-3x better


def test_refinement_gains_two_digits():
    """Acceptance: rgesv_ir/rposv_ir >= 2 decimal digits of backward error
    over plain rgetrs/rpotrs on the §5.1 protocol (n=256 in
    benchmarks/paper_tables.py::bench_refinement; n=128 here for runtime
    — the gain GROWS with n, so this is the conservative cell)."""
    for algo in ("lu", "cholesky"):
        r = refinement_study(128, 1.0, algo, nb=32, iters=3)
        assert r.digits_gained >= 2.0, (algo, r)
        assert r.e_ir < 1e-12, (algo, r)


def test_refinement_multi_rhs_vmapped():
    rng = np.random.default_rng(6)
    n, nrhs = 48, 5
    a64 = rng.standard_normal((n, n))
    b64 = a64 @ rng.standard_normal((n, nrhs))
    a_p = P.from_float64(jnp.asarray(a64))
    b_p = P.from_float64(jnp.asarray(b64))
    (x_hi, x_lo), _ = refine.rgesv_ir(a_p, b_p, iters=2, nb=16)
    assert x_hi.shape == (n, nrhs)
    x64 = np.asarray(refine.pair_to_float64(x_hi, x_lo))
    a64q = np.asarray(P.to_float64(a_p))
    b64q = np.asarray(P.to_float64(b_p))
    res = (np.linalg.norm(b64q - a64q @ x64, axis=0)
           / np.linalg.norm(b64q, axis=0))
    assert res.max() < 1e-10, res
