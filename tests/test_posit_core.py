"""Posit arithmetic vs the independent pure-Python oracle.

Unit values, exhaustive small-format sweeps, and property tests for
add/mul/div/sqrt round-to-nearest-even correctness.  Property tests use
hypothesis when available (pip install -r requirements-dev.txt) and fall
back to a deterministic fixed-seed sweep otherwise, so the file always
collects and tests.
"""
import numpy as np
from fractions import Fraction

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

import posit_oracle as oracle
from repro.core import posit as P
from repro.core.formats import P8E0, P8E2, P16E1, P32E2


def pats(xs):
    return np.asarray(xs, np.int32)


# --------------------------------------------------------------------------
# known values + specials
# --------------------------------------------------------------------------

KNOWN = {0x40000000: 1.0, 0x48000000: 2.0, 0x38000000: 0.5,
         0x3C000000: 0.75, 0x44000000: 1.5, 0x50000000: 4.0,
         0x00000001: 2.0 ** -120, 0x7FFFFFFF: 2.0 ** 120}


def test_known_decodes():
    ps = np.array(list(KNOWN), np.uint32).view(np.int32)
    vals = np.asarray(P.to_float64(ps))
    assert np.array_equal(vals, np.array(list(KNOWN.values())))


def test_specials():
    nar = pats([P32E2.nar_pattern])
    one = np.array([0x40000000], np.uint32).view(np.int32)
    zero = pats([0])
    assert np.isnan(P.to_float64(nar))[0]
    assert np.isnan(P.to_float64(P.div(one, zero)))[0]     # x/0 = NaR
    assert np.isnan(P.to_float64(P.sqrt(P.neg_(one))))[0]  # sqrt(-1) = NaR
    assert int(P.add(zero, zero)[0]) == 0
    assert int(P.add(one, P.neg_(one))[0]) == 0            # exact cancel
    # NaR propagates
    for op in (P.add, P.mul, P.div):
        assert int(op(nar, one)[0]) == P32E2.nar_pattern


def test_is_nar_per_format_oracle():
    """``P.is_nar`` against the exhaustive word-space oracle for every
    narrow format (and sampled + specials for p32): the ONLY word that is
    NaR is the format's sign-extended nar_pattern, so the predicate must
    agree with ``isnan(to_float64(w))`` everywhere — including on the
    redundant sign-extension bits a fault could flip (those words decode
    to ordinary values, never NaR)."""
    for fmt in (P8E0, P8E2, P16E1):
        lo, hi = -(1 << (fmt.nbits - 1)), 1 << (fmt.nbits - 1)
        words = np.arange(lo, hi, dtype=np.int32)        # sign-extended
        got = np.asarray(P.is_nar(words, fmt))
        want = words == fmt.nar_pattern
        assert np.array_equal(got, want), fmt.name
        assert int(got.sum()) == 1                       # exactly one NaR
        assert np.array_equal(got, np.isnan(
            np.asarray(P.to_float64(words, fmt))))
    rng = np.random.default_rng(3)
    w32 = rng.integers(-2**31, 2**31, 4096).astype(np.int32)
    w32 = np.concatenate([w32, pats([P32E2.nar_pattern, 0,
                                     P32E2.maxpos_pattern,
                                     P32E2.minpos_pattern, -1])])
    got = np.asarray(P.is_nar(w32))
    assert np.array_equal(got, w32 == P32E2.nar_pattern)
    assert np.array_equal(got, np.isnan(np.asarray(P.to_float64(w32))))


def test_is_nar_tracks_arithmetic_nar_production():
    """Ops that produce NaR must land exactly on the predicate: x/0,
    sqrt(-1), and NaR propagation through add/mul/div."""
    one = pats([0x40000000])
    zero = pats([0])
    nar = pats([P32E2.nar_pattern])
    assert bool(P.is_nar(P.div(one, zero))[0])
    assert bool(P.is_nar(P.sqrt(P.neg_(one)))[0])
    for op in (P.add, P.mul, P.div):
        assert bool(P.is_nar(op(nar, one))[0])
        assert not bool(P.is_nar(op(one, one))[0])


def test_saturation_no_overflow():
    big = pats([P32E2.maxpos_pattern])
    assert int(P.mul(big, big)[0]) == P32E2.maxpos_pattern
    tiny = pats([P32E2.minpos_pattern])
    assert int(P.mul(tiny, tiny)[0]) == P32E2.minpos_pattern


# --------------------------------------------------------------------------
# exhaustive small-format checks vs the oracle
# --------------------------------------------------------------------------

def test_p8_exhaustive_decode_matches_oracle():
    all_p = np.arange(-127, 128, dtype=np.int32)
    got = np.asarray(P.to_float64(all_p, P8E0))
    want = np.array([float(oracle.decode(int(p), 8, 0)) for p in all_p])
    assert np.array_equal(got, want)


def test_p16_sampled_decode_matches_oracle():
    rng = np.random.default_rng(0)
    all_p = rng.integers(-32767, 32768, size=2000).astype(np.int32)
    got = np.asarray(P.to_float64(all_p, P16E1))
    want = np.array([float(oracle.decode(int(p), 16, 1)) for p in all_p])
    assert np.array_equal(got, want)


def test_p8_exhaustive_add_mul_matches_oracle():
    all_p = np.arange(-127, 128, dtype=np.int32)
    a = np.repeat(all_p, 255)
    b = np.tile(all_p, 255)
    for op, frac_op in [(P.add, lambda x, y: x + y),
                        (P.mul, lambda x, y: x * y)]:
        got = np.asarray(op(a, b, P8E0))
        vals = {int(p): oracle.decode(int(p), 8, 0) for p in all_p}
        want = np.array([oracle.encode(frac_op(vals[int(x)], vals[int(y)]),
                                       8, 0)
                         for x, y in zip(a, b)], np.int32)
        bad = got != want
        assert not bad.any(), (
            f"{int(bad.sum())} mismatches, first at a={a[bad][0]} "
            f"b={b[bad][0]}: got {got[bad][0]} want {want[bad][0]}")


# --------------------------------------------------------------------------
# property tests (p32e2 against the exact rational oracle): hypothesis
# when installed, deterministic fixed-seed sweep otherwise
# --------------------------------------------------------------------------

def _check_add(pa, pb):
    want = oracle.encode(oracle.decode(pa, 32, 2) + oracle.decode(pb, 32, 2),
                         32, 2)
    assert int(P.add(pats([pa]), pats([pb]))[0]) == want, (pa, pb)


def _check_mul(pa, pb):
    want = oracle.encode(oracle.decode(pa, 32, 2) * oracle.decode(pb, 32, 2),
                         32, 2)
    assert int(P.mul(pats([pa]), pats([pb]))[0]) == want, (pa, pb)


def _check_div(pa, pb):
    want = oracle.encode(oracle.decode(pa, 32, 2) / oracle.decode(pb, 32, 2),
                         32, 2)
    assert int(P.div(pats([pa]), pats([pb]))[0]) == want, (pa, pb)


def _check_sqrt(pa):
    want = oracle.sqrt_nearest(oracle.decode(pa, 32, 2), 32, 2)
    assert int(P.sqrt(pats([pa]))[0]) == want, pa


def _check_add_commutes(pa, pb):
    assert int(P.add(pats([pa]), pats([pb]))[0]) == \
        int(P.add(pats([pb]), pats([pa]))[0])


def _check_neg_involution(pa):
    assert int(P.neg_(P.neg_(pats([pa])))[0]) == pa


def _check_from_float64(x):
    got = int(np.asarray(P.from_float64(np.array([x], np.float64)))[0])
    want = oracle.encode(Fraction(x) if x else Fraction(0), 32, 2)
    assert got == want, x


if HAVE_HYPOTHESIS:
    pat32 = st.integers(min_value=-(2 ** 31) + 1, max_value=2 ** 31 - 1)

    @settings(max_examples=150, deadline=None)
    @given(pat32, pat32)
    def test_add_matches_oracle(pa, pb):
        _check_add(pa, pb)

    @settings(max_examples=150, deadline=None)
    @given(pat32, pat32)
    def test_mul_matches_oracle(pa, pb):
        _check_mul(pa, pb)

    @settings(max_examples=150, deadline=None)
    @given(pat32, pat32.filter(lambda p: p != 0))
    def test_div_matches_oracle(pa, pb):
        _check_div(pa, pb)

    @settings(max_examples=100, deadline=None)
    @given(pat32.filter(lambda p: p > 0))
    def test_sqrt_matches_oracle(pa):
        _check_sqrt(pa)

    @settings(max_examples=100, deadline=None)
    @given(pat32, pat32)
    def test_add_commutes(pa, pb):
        _check_add_commutes(pa, pb)

    @settings(max_examples=100, deadline=None)
    @given(pat32)
    def test_negation_involution(pa):
        _check_neg_involution(pa)

    @settings(max_examples=100, deadline=None)
    @given(st.floats(min_value=-1e30, max_value=1e30, allow_nan=False,
                     allow_infinity=False, allow_subnormal=False))
    def test_from_float64_nearest(x):
        # (f64 subnormals excluded: XLA CPU flushes them to zero at the
        # input boundary, so the oracle comparison is environment-dependent)
        _check_from_float64(x)

else:
    # deterministic fallback: fixed-seed patterns + hand-picked edges so
    # the oracle pinning still runs where hypothesis isn't installed
    _EDGES = [1, -1, 2, 0x40000000, -0x40000000, 0x7FFFFFFF, -0x7FFFFFFF,
              0x00000003, 0x38000000, -0x00000002]
    _RNG = np.random.default_rng(20240714)
    _SWEEP = [int(p) for p in
              _RNG.integers(-(2 ** 31) + 1, 2 ** 31, size=120)] + _EDGES

    def test_add_matches_oracle():
        for pa, pb in zip(_SWEEP, reversed(_SWEEP)):
            _check_add(pa, pb)

    def test_mul_matches_oracle():
        for pa, pb in zip(_SWEEP, _SWEEP[7:] + _SWEEP[:7]):
            _check_mul(pa, pb)

    def test_div_matches_oracle():
        for pa, pb in zip(_SWEEP, _SWEEP[3:] + _SWEEP[:3]):
            if pb != 0:
                _check_div(pa, pb)

    def test_sqrt_matches_oracle():
        for pa in _SWEEP:
            if pa > 0:
                _check_sqrt(pa)

    def test_add_commutes():
        for pa, pb in zip(_SWEEP[:40], _SWEEP[40:80]):
            _check_add_commutes(pa, pb)

    def test_negation_involution():
        for pa in _SWEEP[:60]:
            _check_neg_involution(pa)

    def test_from_float64_nearest():
        xs = _RNG.standard_normal(60) * np.exp(_RNG.uniform(-60, 60, 60))
        for x in np.concatenate([xs, [0.0, 1.0, -1.0, 1e30, -1e30]]):
            _check_from_float64(float(x))


# --------------------------------------------------------------------------
# backends agree; f32-native codec agrees with f64 codec
# --------------------------------------------------------------------------

def test_fast_backend_agrees_with_exact():
    rng = np.random.default_rng(3)
    for scale in (1.0, 1e-8, 1e8, 1e-25, 1e25):
        a = P.from_float64(rng.standard_normal(5000) * scale)
        b = P.from_float64(rng.standard_normal(5000) * scale)
        for name in ("add", "mul", "div"):
            ex = np.asarray(P._EXACT[name](a, b))
            fa = np.asarray(P._FAST[name](a, b))
            assert np.array_equal(ex, fa), (name, scale)


def test_f32_native_codec():
    rng = np.random.default_rng(4)
    x = (rng.standard_normal(20000) * np.exp(
        rng.uniform(-20, 20, 20000))).astype(np.float32)
    for fmt in (P16E1, P8E0, P8E2, P32E2):
        via32 = np.asarray(P.from_float32_bits(x, fmt))
        via64 = np.asarray(P.from_float64(x.astype(np.float64), fmt))
        assert np.array_equal(via32, via64), fmt.name
        back = np.asarray(P.to_float32_bits(via32, fmt))
        assert np.isfinite(back).all()


def test_from_float32_bits_matches_oracle():
    """TPU-legal f32 bit path vs the exact rational oracle (p32e2/p16e1):
    from_float32_bits must be the correctly-rounded posit of the exact
    f32 value (every f32 is a dyadic rational — Fraction is exact)."""
    rng = np.random.default_rng(7)
    xs = (rng.standard_normal(300) * np.exp(rng.uniform(-40, 40, 300))
          ).astype(np.float32)
    xs = np.concatenate([xs, np.array([0.0, 1.0, -1.0, 2.0 ** -30,
                                       2.0 ** 30, 3.3e38], np.float32)])
    for fmt in (P32E2, P16E1):
        got = np.asarray(P.from_float32_bits(xs, fmt))
        for x, g in zip(xs, got):
            want = oracle.encode(Fraction(float(x)), fmt.nbits, fmt.es)
            assert int(g) == want, (fmt.name, x)


def test_to_float32_bits_matches_oracle():
    """posit -> f32 without f64: must equal the exact value rounded RNE
    to f32 (p16e1 is exactly representable; p32e2 rounds)."""
    rng = np.random.default_rng(8)
    for fmt, nb in ((P32E2, 32), (P16E1, 16)):
        half = 1 << (nb - 1)
        ps = rng.integers(-half + 1, half, size=400).astype(np.int32)
        got = np.asarray(P.to_float32_bits(ps, fmt))
        for p, g in zip(ps, got):
            want = np.float32(float(oracle.decode(int(p), nb, fmt.es)))
            assert np.float32(g) == want, (fmt.name, int(p))


def test_f32_bit_path_roundtrip():
    """Round-trips: p16e1 words survive posit->f32->posit exactly (every
    p16e1 value is f32-representable); for p32e2 the f64 codec round-trip
    from_float64(to_float64(p)) == p is the exactness statement."""
    rng = np.random.default_rng(9)
    p16 = rng.integers(-(1 << 15) + 1, 1 << 15, size=4000).astype(np.int32)
    back16 = np.asarray(P.from_float32_bits(P.to_float32_bits(p16, P16E1),
                                            P16E1))
    assert np.array_equal(back16, p16)
    p32 = rng.integers(-(1 << 31) + 1, 1 << 31, size=4000).astype(np.int32)
    back32 = np.asarray(P.from_float64(P.to_float64(p32, P32E2), P32E2))
    assert np.array_equal(back32, p32)


def test_golden_zone_eps():
    # paper §2: eps_posit beats binary32's ~6e-8 exactly inside
    # 1e-3 < |x| < 1e3 (fs >= 24 there)
    xs = np.array([1.0, 0.01, 100.0, 999.0, 1.1e-3])
    eps = np.asarray(P.rounding_eps(xs))
    assert (eps < 6e-8).all()
    xs_out = np.array([1e6, 1e-6, 1e12])
    eps_out = np.asarray(P.rounding_eps(xs_out))
    assert (eps_out > 6e-8).all()
