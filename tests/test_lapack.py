"""Posit LAPACK layer: factorizations, solves, the paper's §5.1 protocol."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import posit as P
from repro.lapack import decomp, solve
from repro.lapack.blas import rtrsm_left_lower, rtrsv_lower, rtrsv_upper
from repro.lapack.error_eval import backward_error_study


def test_rtrsm_left_lower():
    rng = np.random.default_rng(0)
    n, m = 24, 8
    l64 = np.tril(rng.standard_normal((n, n))) + 4 * np.eye(n)
    b64 = rng.standard_normal((n, m))
    lp = P.from_float64(jnp.asarray(l64))
    bp = P.from_float64(jnp.asarray(b64))
    x = np.asarray(P.to_float64(rtrsm_left_lower(lp, bp, unit_diag=False)))
    want = np.linalg.solve(l64, b64)
    assert np.abs(x - want).max() / np.abs(want).max() < 1e-6


def test_rtrsv_roundtrip():
    rng = np.random.default_rng(1)
    n = 32
    l64 = np.tril(rng.standard_normal((n, n))) + 4 * np.eye(n)
    x64 = rng.standard_normal(n)
    b64 = l64 @ x64
    lp = P.from_float64(jnp.asarray(l64))
    bp = P.from_float64(jnp.asarray(b64))
    x = np.asarray(P.to_float64(rtrsv_lower(lp, bp)))
    assert np.abs(x - x64).max() / np.abs(x64).max() < 1e-5
    u64 = l64.T
    bu = u64 @ x64
    xu = np.asarray(P.to_float64(rtrsv_upper(
        P.from_float64(jnp.asarray(u64)), P.from_float64(jnp.asarray(bu)))))
    assert np.abs(xu - x64).max() / np.abs(x64).max() < 1e-5


@pytest.mark.parametrize("nb", [16, 32])
def test_rpotrf_reconstruction(nb):
    rng = np.random.default_rng(2)
    n = 64
    x = rng.standard_normal((n, n))
    a64 = x.T @ x
    lp = decomp.rpotrf(P.from_float64(jnp.asarray(a64)), nb=nb)
    lv = np.asarray(P.to_float64(lp))
    assert np.triu(lv, 1).max() == 0.0          # upper zeroed
    rec = lv @ lv.T
    assert np.linalg.norm(rec - a64) / np.linalg.norm(a64) < 1e-6


@pytest.mark.parametrize("gemm_backend", ["xla_quire", "faithful"])
def test_rgetrf_reconstruction(gemm_backend):
    rng = np.random.default_rng(3)
    n = 48
    a64 = rng.standard_normal((n, n))
    lup, ipiv = decomp.rgetrf(P.from_float64(jnp.asarray(a64)), nb=16,
                              gemm_backend=gemm_backend)
    luv = np.asarray(P.to_float64(lup))
    lm = np.tril(luv, -1) + np.eye(n)
    um = np.triu(luv)
    pa = a64.copy()
    for kk, pv in enumerate(np.asarray(ipiv)):
        pa[[kk, pv], :] = pa[[pv, kk], :]
    assert np.linalg.norm(lm @ um - pa) / np.linalg.norm(pa) < 1e-6


def test_solves_recover_solution():
    rng = np.random.default_rng(4)
    n = 48
    x = rng.standard_normal((n, n))
    a64 = x.T @ x
    xs = np.full(n, 1 / np.sqrt(n))
    b64 = a64 @ xs
    lp = decomp.rpotrf(P.from_float64(jnp.asarray(a64)), nb=16)
    xh = np.asarray(P.to_float64(solve.rpotrs(
        lp, P.from_float64(jnp.asarray(b64)))))
    assert np.linalg.norm(xh - xs) / np.linalg.norm(xs) < 1e-4

    a64g = rng.standard_normal((n, n))
    bg = a64g @ xs
    lup, ipiv = decomp.rgetrf(P.from_float64(jnp.asarray(a64g)), nb=16)
    xg = np.asarray(P.to_float64(solve.rgetrs(
        lup, ipiv, P.from_float64(jnp.asarray(bg)))))
    assert np.linalg.norm(xg - xs) / np.linalg.norm(xs) < 1e-4


def test_paper_protocol_golden_zone_advantage():
    """Fig. 7 headline: Posit(32,2) beats binary32 by > 0 digits at
    sigma = 1 (paper reports ~+0.5 (Cholesky) / ~+0.8 (LU))."""
    r = backward_error_study(64, 1.0, "lu", nb=16, gemm_backend="faithful")
    assert r.digits > 0.2, r
    r2 = backward_error_study(64, 1.0, "cholesky", nb=16,
                              gemm_backend="faithful")
    assert r2.digits > 0.2, r2


def test_paper_protocol_large_sigma_disadvantage():
    """Fig. 7: at sigma >= 1e4 the advantage collapses (golden zone)."""
    r = backward_error_study(64, 1e6, "cholesky", nb=16,
                             gemm_backend="faithful")
    assert r.digits < 0.2, r
