"""Posit-quantized serving: weight round-trips against the rational
oracle, quantized forward through every family, scanned prefill pinned
bit-identical to the per-token loop, and the continuous-batching
engine's batched == sequential identity over the paged posit KV-cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import posit_oracle as oracle
from repro.configs import get_tiny_config, tiny_config
from repro.core import posit
from repro.core.formats import get_format
from repro.models import forward_prefill, init_params
from repro.models.common import Axes
from repro.serving import (Engine, PagedKVSpec, PagePool, QuantConfig,
                           Request, generate, param_bytes, prefill,
                           prefill_loop, quantize_params,
                           weight_golden_zone)
from repro.serving.quantize import (channel_scale_exp, dequant_leaf,
                                    quant_matmul, quantize_leaf)

FMTS = ("p32e2", "p16e1", "p8e2")


def _leaf(w):
    return {"w": jnp.asarray(w, jnp.float32), "axes": Axes((None,) * w.ndim)}


# --------------------------------------------------------------------------
# round-trips / scales / hygiene
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fmt_name", FMTS)
def test_pack_unpack_matches_oracle(fmt_name):
    """Every packed word equals the rational oracle's nearest-even
    encode of the equilibrated weight, and unpack returns exactly the
    oracle's value of that word (scaled back)."""
    fmt = get_format(fmt_name)
    rng = np.random.default_rng(3)
    w = (rng.standard_normal((12, 5)) *
         np.exp2(rng.integers(-6, 7, (12, 5)))).astype(np.float32)
    ql = quantize_leaf(_leaf(w), QuantConfig(fmt=fmt_name))
    words = np.asarray(ql["qw"], np.int64)
    sexp = np.asarray(ql["sexp"], np.int64)
    deq = np.asarray(dequant_leaf(ql))
    for i in range(w.shape[0]):
        for j in range(w.shape[1]):
            from fractions import Fraction
            scaled = Fraction(float(w[i, j])) / Fraction(2) ** int(sexp[j])
            want = oracle.encode(scaled, fmt.nbits, fmt.es)
            assert int(words[i, j]) == want, (fmt_name, i, j)
            val = oracle.decode(want, fmt.nbits, fmt.es)
            back = val * Fraction(2) ** int(sexp[j])
            assert float(back) == deq[i, j], (fmt_name, i, j)


@pytest.mark.parametrize("fmt_name", FMTS)
def test_lattice_roundtrip_value_exact(fmt_name):
    """Weights already on the (channel-scaled) posit lattice round-trip
    pack -> unpack exactly."""
    fmt = get_format(fmt_name)
    rng = np.random.default_rng(0)
    # lattice points inside one binade [1,2) (regime k=0, uniform
    # fraction spacing — closed under the quantizer's own pow2
    # equilibration) x exact pow2 channel scales
    mag = rng.uniform(1.0, 2.0, (16, 6)) * rng.choice([-1.0, 1.0], (16, 6))
    raw = np.asarray(
        posit.to_float32_bits(
            posit.from_float32_bits(
                jnp.asarray(mag, jnp.float32), fmt), fmt))
    scales = np.exp2(rng.integers(-8, 9, (6,))).astype(np.float32)
    w = raw * scales
    ql = quantize_leaf(_leaf(w), QuantConfig(fmt=fmt_name))
    assert np.array_equal(np.asarray(dequant_leaf(ql)), w)


def test_channel_scales_exactly_invertible():
    """2^e scaling is exact in f32: scale then unscale is the identity
    for every leaf magnitude the initializer produces."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((32, 8)) * 1e-3, jnp.float32)
    e = channel_scale_exp(w).astype(jnp.float32)
    down = w * jnp.exp2(-e)[None, :]
    up = down * jnp.exp2(e)[None, :]
    assert np.array_equal(np.asarray(up), np.asarray(w))
    # and the scale puts each nonzero channel's max into [1, 2)
    mx = np.abs(np.asarray(down)).max(axis=0)
    assert ((mx >= 1.0) & (mx < 2.0)).all()


def test_stacked_leaf_scales_per_layer():
    """A stacked (np_, d_in, d_out) scan leaf gets independent
    per-layer-per-channel scales (reduction over the contraction axis
    only)."""
    rng = np.random.default_rng(2)
    w = np.stack([rng.standard_normal((6, 4)),
                  rng.standard_normal((6, 4)) * 1024.0])
    e = np.asarray(channel_scale_exp(jnp.asarray(w, jnp.float32)))
    assert e.shape == (2, 4)
    assert (e[1] > e[0]).all()


def test_nar_hygiene_and_saturation():
    wn = np.ones((4, 4), np.float32)
    wn[1, 2] = np.nan
    with pytest.raises(ValueError, match="NaR"):
        quantize_params({"lin": {"w": _leaf(wn)}})
    qp = quantize_params({"lin": {"w": _leaf(wn)}}, allow_nar=True)
    fmt = get_format("p16e1")
    nar = np.asarray(posit.is_nar(
        jnp.asarray(qp["lin"]["w"]["qw"], jnp.int32), fmt))
    assert nar.sum() == 1 and nar[1, 2]
    # out-of-range weights saturate at +-maxpos (per_channel=False keeps
    # raw magnitudes) — finite, no NaR
    big = np.full((2, 3), 1e30, np.float32)
    qb = quantize_leaf(_leaf(big),
                       QuantConfig(fmt="p8e2", per_channel=False))
    deq = np.asarray(dequant_leaf(qb))
    assert np.isfinite(deq).all()
    assert not np.asarray(posit.is_nar(
        jnp.asarray(qb["qw"], jnp.int32), get_format("p8e2"))).any()


def test_param_bytes_storage_saving():
    cfg = get_tiny_config("qwen2-0.5b", policy="f32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, QuantConfig(fmt="p16e1"))
    pb = param_bytes(qp)
    assert pb["q_f32_bytes"] / pb["word_bytes"] == pytest.approx(2.0)
    assert pb["q_f32_bytes"] / (pb["word_bytes"] + pb["scale_bytes"]) > 1.9
    assert pb["f32_bytes"] / pb["bytes"] > 1.9
    q8 = param_bytes(quantize_params(params, QuantConfig(fmt="p8e2")))
    assert q8["q_f32_bytes"] / q8["word_bytes"] == pytest.approx(4.0)
    assert 0.0 < weight_golden_zone(qp) <= 1.0


def test_quant_matmul_backends_agree():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((40, 24)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.standard_normal((6, 40)), jnp.float32)
    yx = quant_matmul(x, quantize_leaf(
        _leaf(w), QuantConfig(fmt="p16e1", backend="xla")))
    yp = quant_matmul(x, quantize_leaf(
        _leaf(w), QuantConfig(fmt="p16e1", backend="pallas")))
    # pallas also rounds the activations to the lattice — close, not
    # bitwise
    assert float(jnp.linalg.norm(yx - yp) / jnp.linalg.norm(yx)) < 1e-3


# --------------------------------------------------------------------------
# quantized forward through every family
# --------------------------------------------------------------------------

FAMILY_ARCHS = ["qwen2-0.5b", "granite-moe-1b-a400m", "mamba2-780m",
                "zamba2-2.7b", "gemma3-12b", "whisper-tiny",
                "internvl2-26b"]


def _tiny_batch(cfg, b=2, s=8, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((b, cfg.enc_seq, cfg.d_model), 0.1,
                                   jnp.float32)
    if cfg.family == "vlm":
        batch["vis"] = jnp.full((b, cfg.vis_tokens, cfg.d_model), 0.1,
                                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_quantized_prefill_every_family(arch):
    cfg = get_tiny_config(arch, policy="f32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _tiny_batch(cfg)
    ref = forward_prefill(params, batch, cfg)
    out = forward_prefill(
        quantize_params(params, QuantConfig(fmt="p16e1")), batch, cfg)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert np.isfinite(np.asarray(out)).all()
    assert rel < 0.02, (arch, rel)


# --------------------------------------------------------------------------
# scanned prefill == per-token loop (the dispatch-cost fix)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m"])
def test_prefill_scan_bit_identical_to_loop(arch):
    cfg = get_tiny_config(arch, policy="f32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 7), 0, cfg.vocab))
    c1, t1, p1 = prefill(params, cfg, toks, 32)
    c2, t2, p2 = prefill_loop(params, cfg, toks, 32)
    assert p1 == p2 and np.array_equal(np.asarray(t1), np.asarray(t2))
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# engine: allocator, batched == sequential, engine == generate
# --------------------------------------------------------------------------

def test_page_pool_allocator():
    cfg = get_tiny_config("qwen2-0.5b", policy="f32")
    spec = PagedKVSpec(page_size=4, n_pages=9, max_batch=2, max_pages=4,
                      fmt="p16e1")
    pool = PagePool(cfg, spec)
    assert len(pool.free) == 8                  # page 0 reserved
    pool.alloc_row(0, 3)
    assert pool.pages_in_use() == 3
    assert not pool.can_alloc(6)
    # positional order: linear index grows with position inside a page
    li = [pool.linear_index(0, t) for t in range(8)]
    assert li[1] == li[0] + 1 and li[5] == li[4] + 1
    # positions past the allocation hit the out-of-bounds drop sentinel
    assert pool.linear_index(0, 12) == spec.n_pages * spec.page_size
    pool.free_row(0)
    assert pool.pages_in_use() == 0 and len(pool.free) == 8
    with pytest.raises(AssertionError):
        pool.alloc_row(0, 9)


def _run_engine(params, cfg, reqs, *, max_inflight, kv_fmt,
                max_batch=3):
    eng = Engine(params, cfg, max_batch=max_batch, page_size=8,
                 max_seq=64, kv_fmt=kv_fmt, max_inflight=max_inflight)
    return eng.run([dataclasses.replace(r) for r in reqs]), eng


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m"])
@pytest.mark.parametrize("kv_fmt", [None, "p16e1"])
def test_engine_batched_bit_identical_to_sequential(arch, kv_fmt):
    """The acceptance gate: continuous-batched decode over paged posit
    KV produces bit-identical tokens to one-request-at-a-time decode
    through the same engine."""
    cfg = get_tiny_config(arch, policy="f32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    if kv_fmt is not None:
        params = quantize_params(params, QuantConfig(fmt="p16e1"))
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (4 + 3 * i,))
                    .astype(np.int32),
                    max_new=5 + i) for i in range(4)]
    batched, _ = _run_engine(params, cfg, reqs, max_inflight=3,
                             kv_fmt=kv_fmt)
    seq, _ = _run_engine(params, cfg, reqs, max_inflight=1,
                         kv_fmt=kv_fmt)
    assert set(batched) == set(seq) == {0, 1, 2, 3}
    for rid in batched:
        assert np.array_equal(batched[rid], seq[rid]), rid


def test_engine_matches_generate():
    """f32 engine output == the dense-cache greedy decode for a dense
    arch (same cache semantics once the ring never wraps)."""
    cfg = get_tiny_config("qwen2-0.5b", policy="f32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab))
    out, _ = _run_engine(
        params, cfg, [Request(rid=0, prompt=prompt[0], max_new=8)],
        max_inflight=1, kv_fmt=None)
    ref = generate(params, cfg, prompt, max_new=8,
                   cache_len=Engine(params, cfg, max_batch=3, page_size=8,
                                    max_seq=64).spec.s_gather)
    assert np.array_equal(out[0], ref[0])


def test_engine_page_pressure_queues_and_drains():
    """More requests than pages: admission waits for frees, everything
    still completes, and pages fully recycle."""
    cfg = get_tiny_config("qwen2-0.5b", policy="f32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_batch=2, page_size=8, max_seq=32,
                 n_pages=5, kv_fmt="p16e1")
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, (6,))
                    .astype(np.int32), max_new=4) for i in range(5)]
    out = eng.run(reqs)
    assert set(out) == set(range(5))
    assert all(len(v) == 4 for v in out.values())
    assert eng.pool.pages_in_use() == 0


def test_engine_eos_stops_early():
    cfg = get_tiny_config("qwen2-0.5b", policy="f32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_batch=2, page_size=8, max_seq=64)
    base = eng.run([Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                            max_new=8)])
    eos = int(base[0][2])
    eng2 = Engine(params, cfg, max_batch=2, page_size=8, max_seq=64)
    out = eng2.run([Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                            max_new=8, eos_id=eos)])
    assert len(out[0]) == 3 and out[0][-1] == eos


def test_tiny_configs_are_tiny():
    for arch in FAMILY_ARCHS:
        cfg = tiny_config(arch)
        assert cfg.vocab <= 128 and "tiny" in cfg.name
        assert cfg.n_layers == get_tiny_config(arch).n_layers
