"""Per-architecture smoke tests: reduced same-family configs, one forward +
one full train step (grad + AdamW) on CPU, serve step, shape/NaN checks.

The FULL configs are exercised only via the dry-run (spec: ARCHITECTURES
block)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import forward_train, init_cache, init_params, serve_step
from repro.models.lm import forward_prefill
from repro.optim import adamw_init


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "targets": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((b, cfg.enc_seq, cfg.d_model), 0.1,
                                   jnp.float32)
    if cfg.family == "vlm":
        batch["vis"] = jnp.full((b, cfg.vis_tokens, cfg.d_model), 0.1,
                                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = forward_train(params, batch, cfg)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # remat path gives the identical loss
    loss_r, _ = forward_train(params, batch, cfg, remat=True)
    assert float(loss) == float(loss_r)
    # one full optimizer step
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, remat=False, lr=1e-3))
    p2, o2, m2 = step(params, opt, batch)
    assert np.isfinite(float(m2["loss"]))
    assert np.isfinite(float(m2["grad_norm"]))
    # params actually changed
    w0 = jax.tree.leaves(params)[0]
    w1 = jax.tree.leaves(p2)[0]
    assert not np.array_equal(np.asarray(w0), np.asarray(w1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = 2
    cache = init_cache(cfg, b, 64)
    if cfg.family == "encdec":
        from repro.models import attention as attn_mod
        from repro.models.lm import _encoder
        pol = cfg.get_policy()
        dt = jnp.dtype(pol.compute_dtype)
        enc = _encoder(params, _batch(cfg)["frames"], cfg, pol, dt)
        cache["cross_kv"] = jax.vmap(
            lambda lp: attn_mod.cross_kv_init(lp["xattn"], enc, cfg, pol,
                                              dt))(params["layers"][0])
    tok = jnp.ones((b, 1), jnp.int32)
    for pos in range(3):
        logits, cache = serve_step(params, cache, tok, jnp.int32(pos), cfg)
        assert logits.shape == (b, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_prefill_matches_decode_path():
    """Next-token logits from the prefill forward must match running the
    decode path token-by-token (independent cache implementations)."""
    cfg = get_smoke_config("qwen2-0.5b")
    params = init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    pre = forward_prefill(params, {"tokens": tokens}, cfg)      # (B, V)
    cache = init_cache(cfg, b, 32)
    logits = None
    for i in range(s):
        logits, cache = serve_step(params, cache, tokens[:, i:i + 1],
                                   jnp.int32(i), cfg)
    pre_np = np.asarray(pre, np.float32)
    dec_np = np.asarray(logits, np.float32)
    # bf16 paths differ in op order: bound the absolute gap and require
    # identical greedy decisions
    assert np.abs(pre_np - dec_np).max() < 0.05
    assert (pre_np.argmax(-1) == dec_np.argmax(-1)).all()


def test_prefill_matches_decode_path_ssm():
    """Same consistency check through the Mamba2 recurrent cache."""
    cfg = get_smoke_config("mamba2-780m")
    params = init_params(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    pre = forward_prefill(params, {"tokens": tokens}, cfg)
    cache = init_cache(cfg, b, 32)
    logits = None
    for i in range(s):
        logits, cache = serve_step(params, cache, tokens[:, i:i + 1],
                                   jnp.int32(i), cfg)
    pre_np = np.asarray(pre, np.float32)
    dec_np = np.asarray(logits, np.float32)
    assert np.abs(pre_np - dec_np).max() < 0.08
    assert (pre_np.argmax(-1) == dec_np.argmax(-1)).all()


def test_local_window_masks_long_range():
    """A gemma3-style local layer must not attend beyond its window."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("gemma3-12b"),
                              n_layers=3, local_ratio=2, local_window=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 16
    t1 = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab)
    # perturb a token far outside every window of the LAST query position
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)
    h1, _ = forward_train(params, {"tokens": t1, "targets": t1}, cfg)
    # compare last-position prefill logits instead of loss
    p1 = forward_prefill(params, {"tokens": t1}, cfg)
    p2 = forward_prefill(params, {"tokens": t2}, cfg)
    # token 0 can still reach the last position through the GLOBAL layer,
    # so we only require finite outputs here
    assert np.isfinite(np.asarray(p1, np.float32)).all()
    assert np.isfinite(np.asarray(p2, np.float32)).all()


def test_full_configs_match_spec():
    """The exact published dimensions from the assignment table."""
    spec = {
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, d_ff=1536,
                             vocab=51865),
        "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    d_ff=1408, vocab=163840, n_experts=64,
                                    top_k=6),
        "granite-moe-1b-a400m": dict(n_layers=24, d_model=1024, n_heads=16,
                                     n_kv_heads=8, d_ff=512, vocab=49155,
                                     n_experts=32, top_k=8),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            d_ff=10240, vocab=32000, ssm_state=64),
        "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14,
                           n_kv_heads=2, d_ff=4864, vocab=151936,
                           qkv_bias=True),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab=128256),
        "gemma3-12b": dict(n_layers=48, d_model=3840, n_heads=16,
                           n_kv_heads=8, d_ff=15360, vocab=262144,
                           local_ratio=5),
        "starcoder2-7b": dict(n_layers=32, d_model=4608, n_heads=36,
                              n_kv_heads=4, d_ff=18432, vocab=49152),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280,
                            ssm_state=128),
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92553),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_grouped_mm_gradients_match_dense_reference():
    """The MoE grouped GEMM's custom VJP (dtype-correct cotangents; fixes
    the scan-transpose AssertionError in the MoE train step) must agree
    with a dense per-row reference on both operand gradients."""
    import jax.numpy as jnp
    from repro.models.ffn import _grouped_mm

    rng = np.random.default_rng(0)
    t, d, f, e = 12, 5, 7, 3
    gs = jnp.array([4, 3, 5], jnp.int32)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32)
    gid = np.repeat(np.arange(e), np.asarray(gs))

    def ref(x, w):
        return sum((x[i] @ w[gid[i]]).sum() for i in range(t))

    def ours(x, w):
        return _grouped_mm(x, w, gs).sum()

    gx1, gw1 = jax.grad(ref, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(ours, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-5)
