"""Exact-ABFT fault tolerance (repro.ft) — DESIGN.md §11 acceptance.

1. **Checksums are exact and total** — quire-limb column/row sums plus
   raw word sums detect ANY stored-word change: 100% detection of seeded
   single-word faults (flip / NaR / saturate, every protected driver),
   zero false positives across the fault-free §5.1 sigma grid.
2. **Recovery is bit-identical** — a detected step is recomputed from
   verified pre-step state; the repaired result equals the unprotected
   fault-free words exactly.
3. **Injection is deterministic** — same seed + schedule gives identical
   injected words eager, under jit, under vmap, and on 2x2 / 1x8 device
   grids (the soak-test precondition).
4. **Graceful degradation** — the monitored refinement ladder
   (rgesv_mp -> rgesv_ir -> plain) stalls/falls back per SolveReport.
5. **Zero cost when unused** — the unprotected public entry points lower
   to byte-identical text as their frozen jitted programs (the
   tests/test_obs.py mechanism: FT rode along without touching them).
"""
from __future__ import annotations

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import posit as P
from repro.core.formats import P16E1, P32E2
from repro.kernels import ops
from repro.lapack import decomp, error_eval, qr, refine, solve
from repro import ft
from repro.ft import Fault, FaultPlan, make_plan
from repro.ft.abft import AbftError


def _pm(rng, shape, fmt=P32E2, lo=-4, hi=4):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return P.from_float64(jnp.asarray(x), fmt)


def _spd(rng, n):
    x = rng.standard_normal((n, n))
    return P.from_float64(jnp.asarray(x @ x.T + n * np.eye(n)))


def _eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# 1. checksums: exactness, localization, total coverage of word bits
# --------------------------------------------------------------------------

def test_checksum_verify_and_locate():
    rng = np.random.default_rng(0)
    a = _pm(rng, (24, 16))
    cks = ft.checksum(a)
    ok, _, _ = ft.verify(a, cks)
    assert bool(ok)
    bad = np.asarray(a).copy()
    bad[5, 11] ^= 1 << 13
    ok, bad_row, bad_col = ft.verify(jnp.asarray(bad), cks)
    assert not bool(ok)
    assert ft.locate(bad_row, bad_col) == (5, 11)
    assert ft.locate(bad_row, bad_col, nb=8) == (0, 1)


def test_checksum_detects_sign_extension_bit_flip_p16e1():
    """p16e1 words are stored sign-extended in int32: a flip in the
    redundant upper bits doesn't change the decoded VALUE, so the limb
    checksums alone can't see it — the raw word sums must."""
    rng = np.random.default_rng(1)
    a = _pm(rng, (8, 8), fmt=P16E1)
    cks = ft.checksum(a, fmt=P16E1)
    bad = np.asarray(a).copy()
    bad[3, 3] ^= 1 << 20                      # above the 16-bit payload
    ok, bad_row, bad_col = ft.verify(jnp.asarray(bad), cks, fmt=P16E1)
    assert not bool(ok)
    assert ft.locate(bad_row, bad_col) == (3, 3)


def test_zero_false_positives_sigma_grid():
    """Fault-free verification over the §5.1 sigma grid: every protected
    driver must report zero detections and bit-identity with its
    unprotected twin on well- and ill-scaled inputs alike."""
    for sigma in (1e-2, 1.0, 1e2, 1e4):
        a64 = error_eval.make_general(32, sigma, seed=3)
        s64 = error_eval.make_spd(32, sigma, seed=3)
        a = P.from_float64(jnp.asarray(a64))
        s = P.from_float64(jnp.asarray(s64))
        c, cks, rep = ft.rgemm_ft(a, a)
        assert _eq(c, ops.rgemm(a, a)) and rep.detections == 0, sigma
        lu, piv, rep = decomp.rgetrf_ft(a, nb=16)
        lu0, piv0 = decomp.rgetrf(a, nb=16)
        assert _eq(lu, lu0) and _eq(piv, piv0) and rep.detections == 0
        l, rep = decomp.rpotrf_ft(s, nb=16)
        assert _eq(l, decomp.rpotrf(s, nb=16)) and rep.detections == 0


# --------------------------------------------------------------------------
# 2. seeded injection: 100% detection, bit-identical recovery
# --------------------------------------------------------------------------

def test_rgemm_ft_detects_and_recovers_all_seeds():
    rng = np.random.default_rng(2)
    a, b = _pm(rng, (24, 16)), _pm(rng, (16, 24))
    ref = ops.rgemm(a, b)
    for seed in range(8):
        plan = make_plan(seed, "rgemm.out", size=24 * 24,
                         kinds=("flip", "nar", "saturate"))
        got, cks, rep = ft.rgemm_ft(a, b, plan=plan)
        assert rep.detections == 1 and rep.retries == 1, seed
        assert _eq(got, ref), seed
        ok, _, _ = ft.verify(got, cks)
        assert bool(ok)


def test_quire_gemm_ft_detects_word_and_limb_faults():
    rng = np.random.default_rng(3)
    a, b = _pm(rng, (16, 12)), _pm(rng, (12, 16))
    ref = ops.rgemm(a, b, backend="quire_exact")
    for site, nbits in (("rgemm.out", 32), ("rgemm.limbs", 64)):
        for seed in range(4):
            plan = make_plan(seed, site, size=16 * 16, nbits=nbits)
            got, cks, rep = ft.quire_gemm_ft(a, b, plan=plan)
            assert rep.detections >= 1, (site, seed)
            assert _eq(got, ref), (site, seed)


@pytest.mark.parametrize("driver,site", [
    ("rpotrf", "rpotrf.step"), ("rgetrf", "rgetrf.step"),
    ("rgeqrf", "rgeqrf.step")])
def test_protected_drivers_detect_and_recover(driver, site):
    rng = np.random.default_rng(4)
    n = 48
    if driver == "rpotrf":
        a = _spd(rng, n)
        ref = decomp.rpotrf(a, nb=16)
        run = lambda plan: decomp.rpotrf_ft(a, nb=16, plan=plan)
        unpack = lambda out: (out[0], out[-1])
    elif driver == "rgetrf":
        a = _pm(rng, (n, n))
        ref = decomp.rgetrf(a, nb=16)
        run = lambda plan: decomp.rgetrf_ft(a, nb=16, plan=plan)
        unpack = lambda out: (out[:-1], out[-1])
    else:
        a = _pm(rng, (n, 32))
        ref = qr.rgeqrf(a, nb=16)
        run = lambda plan: qr.rgeqrf_ft(a, nb=16, plan=plan)
        unpack = lambda out: (out[:-1], out[-1])
    # fault-free: bit-identical, zero detections
    got, rep = unpack(run(None))
    flat_ref = ref if isinstance(ref, tuple) else (ref,)
    flat_got = got if isinstance(got, tuple) else (got,)
    assert all(_eq(g, r) for g, r in zip(flat_got, flat_ref))
    assert rep.detections == 0 and rep.retries == 0
    # seeded single faults on every block step: detected + repaired
    for seed in range(6):
        plan = make_plan(seed, site, size=n * 32, steps=2,
                         kinds=("flip", "nar"))
        got, rep = unpack(run(plan))
        flat_got = got if isinstance(got, tuple) else (got,)
        assert rep.detections >= 1, seed
        assert all(_eq(g, r) for g, r in zip(flat_got, flat_ref)), seed


def test_rgeqrf_ft_detects_tau_fault():
    rng = np.random.default_rng(5)
    a = _pm(rng, (32, 32))
    r0, tau0 = qr.rgeqrf(a, nb=16)
    plan = FaultPlan((Fault(site="rgeqrf.tau", step=1, lane=3, bit=9),))
    r, tau, rep = qr.rgeqrf_ft(a, nb=16, plan=plan)
    assert rep.detections == 1
    assert _eq(r, r0) and _eq(tau, tau0)


def test_abft_error_on_exhausted_budget():
    rng = np.random.default_rng(6)
    a, b = _pm(rng, (8, 8)), _pm(rng, (8, 8))
    plan = FaultPlan((Fault(site="rgemm.out", step=0, lane=5, bit=7),))
    with pytest.raises(AbftError):
        ft.rgemm_ft(a, b, plan=plan, max_retries=0)


# --------------------------------------------------------------------------
# 3. injection determinism: eager == jit == vmap, and across runs
# --------------------------------------------------------------------------

def test_make_plan_deterministic():
    p1 = make_plan(11, "rgemm.out", size=64, steps=3, n=4,
                   kinds=("flip", "nar", "saturate"), devs=4)
    p2 = make_plan(11, "rgemm.out", size=64, steps=3, n=4,
                   kinds=("flip", "nar", "saturate"), devs=4)
    assert p1 == p2 and hash(p1) == hash(p2)
    assert p1 != make_plan(12, "rgemm.out", size=64, steps=3, n=4)


def test_inject_words_eager_jit_vmap_identical():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.integers(-2**31, 2**31, (4, 6, 6)), jnp.int32)
    plan = make_plan(13, "s", size=36, n=3,
                     kinds=("flip", "nar", "saturate"))
    apply1 = lambda x: plan.words("s", 0, x)
    eager = jnp.stack([apply1(w[i]) for i in range(4)])
    jitted = jnp.stack([jax.jit(apply1)(w[i]) for i in range(4)])
    vmapped = jax.vmap(apply1)(w)
    assert _eq(eager, jitted) and _eq(eager, vmapped)
    # idempotent across repeated application of the SAME schedule state
    assert _eq(jax.jit(apply1)(w[0]), apply1(w[0]))


def test_inject_limbs_deterministic_under_jit():
    rng = np.random.default_rng(8)
    l = jnp.asarray(rng.integers(-2**62, 2**62, (5, 16)), jnp.int64)
    plan = make_plan(14, "rgemm.limbs", size=80, n=2, nbits=64)
    f = lambda x: plan.limbs("rgemm.limbs", 0, x)
    assert _eq(f(l), jax.jit(f)(l))
    changed = np.asarray(f(l)) != np.asarray(l)
    assert changed.sum() in (1, 2)             # lanes may collide


# --------------------------------------------------------------------------
# 4. graceful degradation: monitor + solver ladder
# --------------------------------------------------------------------------

def _cond_matrix(n, cond, seed=0):
    rng = np.random.default_rng(seed)
    q1, _ = np.linalg.qr(rng.standard_normal((n, n)))
    q2, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.logspace(0, -np.log10(cond), n)
    return q1 @ np.diag(s) @ q2


def test_monitored_refinement_matches_refine_pair_when_converging():
    rng = np.random.default_rng(9)
    n = 32
    a = P.from_float64(jnp.asarray(_cond_matrix(n, 1e1, seed=1)))
    b = _pm(rng, (n,))
    lu, ipiv = decomp.rgetrf(a, nb=16)
    solve_fn = lambda r: solve.rgetrs(lu, ipiv, r, quire=True)
    residual_fn = lambda hi, lo, bb: refine.residual_quire(a, hi, bb, lo)
    (hi, lo), info = refine.refine_pair_monitored(solve_fn, residual_fn,
                                                  b, max_sweeps=8)
    assert info["outcome"] == "converged"
    hi0, lo0 = refine.refine_pair(solve_fn, residual_fn, b,
                                  iters=info["sweeps"])
    assert _eq(hi, hi0) and _eq(lo, lo0)


def test_guarded_solve_converges_on_benign_matrix():
    rng = np.random.default_rng(10)
    n = 32
    a = P.from_float64(jnp.asarray(_cond_matrix(n, 1e1, seed=2)))
    b = _pm(rng, (n,))
    (hi, lo), rep = refine.rgesv_guarded(a, b, nb=16)
    assert rep.outcome == "converged" and rep.solver == "rgesv_mp"
    assert rep.fallbacks == () and rep.detections == 0
    x = np.asarray(refine.pair_to_float64(hi, lo))
    r = np.asarray(P.to_float64(b)) - _cond_matrix(n, 1e1, seed=2) @ x
    assert np.max(np.abs(r)) < 1e-8 * np.max(np.abs(np.asarray(
        P.to_float64(b))))


def test_guarded_solve_escalates_on_ill_conditioning():
    """cond ~ 1e4: the p16e1 narrow factorization stalls, the ladder
    falls through to full-width IR which converges — the degradation
    path the SolveReport exists to expose."""
    rng = np.random.default_rng(11)
    n = 32
    a = P.from_float64(jnp.asarray(_cond_matrix(n, 1e4, seed=3)))
    b = _pm(rng, (n,))
    (hi, lo), rep = refine.rgesv_guarded(a, b, nb=16)
    assert rep.solver in ("rgesv_ir", "rgetrs")
    assert rep.fallbacks and rep.fallbacks[0][0] == "rgesv_mp"
    assert rep.fallbacks[0][1] in ("stalled", "diverged")


def test_guarded_solve_absorbs_injected_factorization_faults():
    rng = np.random.default_rng(12)
    n = 32
    a = P.from_float64(jnp.asarray(_cond_matrix(n, 1e1, seed=4)))
    b = _pm(rng, (n,))
    pair0, rep0 = refine.rgesv_guarded(a, b, nb=16)
    plan = FaultPlan((Fault(site="rgetrf.step", step=0, lane=17, bit=21),
                      Fault(site="rgetrf.step", step=1, lane=3, bit=5)))
    pair, rep = refine.rgesv_guarded(a, b, nb=16, plan=plan)
    assert rep.detections == 2 and rep.retries == 2
    assert _eq(pair[0], pair0[0]) and _eq(pair[1], pair0[1])
    assert rep.outcome == rep0.outcome


# --------------------------------------------------------------------------
# 5. zero-cost contract: unprotected entry points lower unchanged
# --------------------------------------------------------------------------

def test_unprotected_lowering_identical_to_frozen_programs():
    """FT rides alongside: the public unprotected wrappers must trace to
    byte-identical text as the underlying frozen jitted programs (the
    tests/test_obs.py mechanism — any FT hook leaking into the default
    path would change this text)."""
    rng = np.random.default_rng(13)
    a = _pm(rng, (32, 32))
    spd_a = ops.rgemm(a, a, trans_b=True)
    pairs = [
        (jax.jit(lambda x, y: ops.rgemm(x, y)).lower(a, a),
         jax.jit(lambda x, y: ops._rgemm_jit(x, y)).lower(a, a)),
        (jax.jit(lambda x: decomp.rgetrf(x, nb=16)).lower(a),
         jax.jit(lambda x: decomp._rgetrf_jit(x, nb=16)).lower(a)),
        (jax.jit(lambda x: decomp.rpotrf(x, nb=16)).lower(spd_a),
         jax.jit(lambda x: decomp._rpotrf_jit(x, nb=16)).lower(spd_a)),
        (jax.jit(lambda x: qr.rgeqrf(x, nb=16)).lower(a),
         jax.jit(lambda x: qr._rgeqrf_jit(x, nb=16)).lower(a)),
    ]
    for wrapped, direct in pairs:
        assert wrapped.as_text() == direct.as_text()


# --------------------------------------------------------------------------
# 6. distributed: strip-checksummed broadcasts + checkpoint/restart
# --------------------------------------------------------------------------

_PRELUDE = """
import tempfile
import numpy as np, jax, jax.numpy as jnp
from repro.core import posit as P
from repro.dist import distribute, make_grid_mesh, pdgemm, p_rpotrf, p_rgetrf
from repro.dist.pdecomp import p_rpotrf_ft, p_rgetrf_ft
from repro.dist.pblas import pdgemm_ft
from repro.ft import Fault, FaultPlan, make_plan

rng = np.random.default_rng(7)
def pm(shape, lo=-4, hi=4):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return P.from_float64(jnp.asarray(x))
def spd(n):
    x = rng.standard_normal((n, n))
    return P.from_float64(jnp.asarray(x @ x.T + n * np.eye(n)))
def eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))
"""


@pytest.mark.multi_device
def test_dist_ft_fault_free_identity_and_recovery(multi_device):
    out = multi_device(_PRELUDE + """
a_spd, a_gen = spd(96), pm((96, 96))
for p, q in ((2, 2), (1, 8)):
    mesh = make_grid_mesh(p, q)
    ref = p_rpotrf(distribute(a_spd, mesh, 32)).gather()
    got, rep = p_rpotrf_ft(distribute(a_spd, mesh, 32))
    assert eq(got.gather(), ref) and rep.detections == 0, (p, q)
    ref_lu, ref_piv = p_rgetrf(distribute(a_gen, mesh, 32))
    lu, piv, rep = p_rgetrf_ft(distribute(a_gen, mesh, 32))
    assert eq(lu.gather(), ref_lu.gather()) and eq(piv, ref_piv), (p, q)
    # dev-targeted broadcast fault: detected once, repaired exactly
    plan = FaultPlan((Fault(site="dist.panel", step=1, lane=5, bit=12,
                            dev=min(3, p * q - 1)),))
    got, rep = p_rpotrf_ft(distribute(a_spd, mesh, 32), plan=plan)
    assert rep.detections == 1 and rep.retries == 1, (p, q)
    assert eq(got.gather(), ref), (p, q)
    print("OK", p, q)
print("DONE")
""", timeout=900)
    assert "DONE" in out


@pytest.mark.multi_device
def test_dist_injection_deterministic_across_grids(multi_device):
    """Same seed + schedule on a 2x2 and a 1x8 grid: the dev-gated
    injection fires on the same linear device id, every run detects, and
    both grids recover to the SAME global words."""
    out = multi_device(_PRELUDE + """
a = pm((96, 96))
plan = make_plan(21, "dist.panel", size=96 * 32, steps=3, n=1, devs=4)
outs = []
for p, q in ((2, 2), (1, 8)):
    mesh = make_grid_mesh(p, q)
    runs = []
    for _ in range(2):
        lu, piv, rep = p_rgetrf_ft(distribute(a, mesh, 32), plan=plan)
        assert rep.detections >= 1, (p, q)
        runs.append((np.asarray(lu.gather()), np.asarray(piv)))
    assert eq(runs[0][0], runs[1][0]) and eq(runs[0][1], runs[1][1])
    outs.append(runs[0])
assert eq(outs[0][0], outs[1][0]) and eq(outs[0][1], outs[1][1])
print("DONE")
""", timeout=900)
    assert "DONE" in out


@pytest.mark.multi_device
def test_pdgemm_ft_identity_and_recovery(multi_device):
    out = multi_device(_PRELUDE + """
mesh = make_grid_mesh(2, 2)
a, b = pm((96, 80)), pm((80, 64))
ad, bd = distribute(a, mesh, 32), distribute(b, mesh, 32)
ref = pdgemm(ad, bd).gather()
got, rep = pdgemm_ft(ad, bd)
assert eq(got.gather(), ref) and rep.detections == 0
for site in ("pdgemm.a", "pdgemm.b"):
    plan = FaultPlan((Fault(site=site, step=0, lane=7, bit=20, dev=1),))
    got, rep = pdgemm_ft(ad, bd, plan=plan)
    assert rep.detections == 1 and rep.retries == 1, site
    assert eq(got.gather(), ref), site
print("DONE")
""", timeout=900)
    assert "DONE" in out


@pytest.mark.multi_device
def test_dist_checkpoint_kill_resume_bit_identity(multi_device):
    out = multi_device(_PRELUDE + """
mesh = make_grid_mesh(2, 2)
a_gen, a_spd = pm((96, 96)), spd(96)
ref_lu, ref_piv = p_rgetrf(distribute(a_gen, mesh, 32))
with tempfile.TemporaryDirectory() as ck:
    out, _, rep = p_rgetrf_ft(distribute(a_gen, mesh, 32),
                              checkpoint_dir=ck, _stop_after=1)
    assert out is None                       # simulated kill
    lu, piv, rep = p_rgetrf_ft(distribute(a_gen, mesh, 32),
                               checkpoint_dir=ck, resume=True)
    assert eq(lu.gather(), ref_lu.gather()) and eq(piv, ref_piv)
ref_l = p_rpotrf(distribute(a_spd, mesh, 32)).gather()
with tempfile.TemporaryDirectory() as ck:
    out, rep = p_rpotrf_ft(distribute(a_spd, mesh, 32),
                           checkpoint_dir=ck, _stop_after=2)
    assert out is None
    got, rep = p_rpotrf_ft(distribute(a_spd, mesh, 32),
                           checkpoint_dir=ck, resume=True)
    assert eq(got.gather(), ref_l)
    # the public wrapper delegates to the checkpointing path
    got2 = p_rpotrf(distribute(a_spd, mesh, 32), checkpoint_dir=ck)
    assert eq(got2.gather(), ref_l)
print("DONE")
""", timeout=900)
    assert "DONE" in out
