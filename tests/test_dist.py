"""Distributed == single-device bit-identity (repro.dist acceptance).

The distributed routines' whole contract is that sharding is a pure
*schedule* change: every posit word out of ``pdgemm`` / ``p_rpotrf`` /
``p_rgetrf`` / ``p_rgesv_ir`` must equal the single-device
``rgemm`` / ``rpotrf`` / ``rgetrf`` / ``rgesv_ir`` word bit-for-bit, for
every gemm backend, on both a 2D (2x2) and a degenerate (1x8 / 8x1)
grid, including non-divisible shapes that exercise padding blocks.
Multi-device cases run through the ``multi_device`` subprocess fixture
(8 forced host devices); the layout index math is pure and tests
in-process.
"""
import numpy as np
import pytest

pytestmark = []

_PRELUDE = """
import os
import numpy as np, jax, jax.numpy as jnp
from repro.core import posit as P
from repro.kernels.ops import rgemm
from repro.lapack import decomp, refine
from repro.dist import (distribute, make_grid_mesh, pdgemm,
                        p_residual_quire, p_rpotrf, p_rgetrf, p_rgesv_ir,
                        p_rposv_ir)

rng = np.random.default_rng(7)
def pm(shape, lo=-6, hi=6):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return P.from_float64(jnp.asarray(x))

def eq(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))
"""


# --------------------------------------------------------------------------
# layout index math (in-process; no devices needed)
# --------------------------------------------------------------------------

def test_block_cyclic_roundtrip_and_owner_math():
    import jax.numpy as jnp
    from repro.dist.layout import BlockCyclic, gather_array, scatter_array

    rng = np.random.default_rng(0)
    for (m, n, nb, p, q) in [(96, 96, 32, 2, 2), (96, 80, 32, 2, 4),
                             (65, 130, 32, 1, 8), (40, 40, 16, 8, 1),
                             (33, 67, 32, 3, 2)]:
        lay = BlockCyclic(m=m, n=n, nb=nb, p=p, q=q)
        x = rng.integers(-2**31, 2**31, (m, n), dtype=np.int32)
        d = scatter_array(jnp.asarray(x), lay)
        assert d.shape == (p * lay.lm, q * lay.ln)
        assert np.array_equal(np.asarray(gather_array(d, lay)), x)
        # scatter places global block (bi, bj) at its cyclic owner
        d_np = np.asarray(d)
        for bi, bj in [(0, 0), (1, 1), (m // nb, n // nb)]:
            if bi * nb >= m or bj * nb >= n:
                continue
            r, c = lay.block_owner(bi, bj)
            t, s = bi // p, bj // q
            tile = d_np[r * lay.lm + t * nb, c * lay.ln + s * nb]
            assert tile == x[bi * nb, bj * nb]


def test_layout_col_block_home():
    from repro.dist.layout import BlockCyclic
    lay = BlockCyclic(m=96, n=96, nb=32, p=2, q=2)
    assert lay.col_block_home(0) == (0, 0, 0)
    assert lay.col_block_home(32) == (1, 0, 0)
    assert lay.col_block_home(64) == (0, 1, 32)


# --------------------------------------------------------------------------
# pdgemm: every backend, 2x2 grid, odd shapes
# --------------------------------------------------------------------------

@pytest.mark.multi_device
def test_pdgemm_bit_identity_all_backends_2x2(multi_device):
    out = multi_device(_PRELUDE + """
mesh = make_grid_mesh(2, 2)
shapes = {"xla_quire": [(96, 96, 96), (96, 80, 64)],
          "quire_exact": [(96, 96, 96), (96, 80, 64)],
          "pallas_split3": [(96, 80, 64)],
          "faithful": [(96, 80, 64)]}
for backend, cases in shapes.items():
    for (m, k, n) in cases:
        a, b = pm((m, k)), pm((k, n))
        got = pdgemm(distribute(a, mesh, 32), distribute(b, mesh, 32),
                     backend=backend).gather()
        assert eq(got, rgemm(a, b, backend=backend)), (backend, m, k, n)
        print("OK", backend, (m, k, n))
print("DONE")
""")
    assert "DONE" in out


@pytest.mark.multi_device
def test_pdgemm_format_knob_p16e1(multi_device):
    """The format-parametric dist contract: pdgemm with fmt=p16e1 (both
    schedules) is bit-identical to single-device rgemm in p16e1 — the
    k_split limb planes shrink to the p16e1 quire's 4 limbs and still
    reassociate exactly."""
    out = multi_device(_PRELUDE + """
from repro.core.formats import P16E1
mesh = make_grid_mesh(2, 2)
x = rng.standard_normal((96, 80)); y = rng.standard_normal((80, 64))
a = P.from_float64(jnp.asarray(x), P16E1)
b = P.from_float64(jnp.asarray(y), P16E1)
ad, bd = distribute(a, mesh, 32), distribute(b, mesh, 32)
for backend, ks in (("xla_quire", False), ("quire_exact", False),
                    ("quire_exact", True)):
    got = pdgemm(ad, bd, backend=backend, k_split=ks, fmt=P16E1).gather()
    assert eq(got, rgemm(a, b, backend=backend, fmt=P16E1)), (backend, ks)
    print("OK", backend, ks)
print("DONE")
""")
    assert "DONE" in out


@pytest.mark.multi_device
def test_pdgemm_limb_psum_k_split(multi_device):
    """The quire limb-plane reduction schedule: deposits on each device's
    K slab, psum_scatter over int64 limb planes, ONE rounding — plus the
    alpha/beta folding of the quire_exact contract."""
    out = multi_device(_PRELUDE + """
mesh = make_grid_mesh(2, 2)
a, b, c0 = pm((96, 80)), pm((80, 64)), pm((96, 64))
ad, bd = distribute(a, mesh, 32), distribute(b, mesh, 32)
got = pdgemm(ad, bd, backend="quire_exact", k_split=True).gather()
assert eq(got, rgemm(a, b, backend="quire_exact"))
got = pdgemm(ad, bd, distribute(c0, mesh, 32), alpha=-1.0, beta=1.0,
             backend="quire_exact", k_split=True).gather()
assert eq(got, rgemm(a, b, c0, alpha=-1.0, beta=1.0,
                     backend="quire_exact"))
print("DONE")
""")
    assert "DONE" in out


# --------------------------------------------------------------------------
# distributed factorizations, 2x2 and degenerate grids
# --------------------------------------------------------------------------

@pytest.mark.multi_device
def test_pdecomp_bit_identity_2x2(multi_device):
    out = multi_device(_PRELUDE + """
mesh = make_grid_mesh(2, 2)
n, nb = 96, 32
g = rng.standard_normal((n, n))
sp = P.from_float64(jnp.asarray(g.T @ g + n * np.eye(n)))
gp = P.from_float64(jnp.asarray(g))
for backend in ("xla_quire", "quire_exact", "pallas_split3"):
    got = p_rpotrf(distribute(sp, mesh, nb), gemm_backend=backend).gather()
    assert eq(got, decomp.rpotrf(sp, nb=nb, gemm_backend=backend)), backend
    print("OK rpotrf", backend)
for backend in ("xla_quire", "quire_exact"):
    lu_d, piv_d = p_rgetrf(distribute(gp, mesh, nb), gemm_backend=backend)
    lu, piv = decomp.rgetrf(gp, nb=nb, gemm_backend=backend)
    assert eq(lu_d.gather(), lu) and eq(piv_d, piv), backend
    print("OK rgetrf", backend)
print("DONE")
""", timeout=900)
    assert "DONE" in out


@pytest.mark.multi_device
def test_pdecomp_degenerate_grids(multi_device):
    """1x8 (all-column) and 8x1 (all-row) grids: more devices than real
    blocks on one axis, so some devices hold only padding."""
    out = multi_device(_PRELUDE + """
n, nb = 96, 32
g = rng.standard_normal((n, n))
sp = P.from_float64(jnp.asarray(g.T @ g + n * np.eye(n)))
gp = P.from_float64(jnp.asarray(g))
m18 = make_grid_mesh(1, 8)
m81 = make_grid_mesh(8, 1)
a, b = pm((96, 80)), pm((80, 64))
for mesh, tag in ((m18, "1x8"), (m81, "8x1")):
    ad, bd = distribute(a, mesh, nb), distribute(b, mesh, nb)
    for backend in ("xla_quire", "quire_exact", "pallas_split3",
                    "faithful"):
        got = pdgemm(ad, bd, backend=backend).gather()
        assert eq(got, rgemm(a, b, backend=backend)), (tag, backend)
    got = pdgemm(ad, bd, backend="quire_exact", k_split=True).gather()
    assert eq(got, rgemm(a, b, backend="quire_exact")), (tag, "k_split")
    print("OK pdgemm all backends", tag)
lu_d, piv_d = p_rgetrf(distribute(gp, m18, nb))
lu, piv = decomp.rgetrf(gp, nb=nb)
assert eq(lu_d.gather(), lu) and eq(piv_d, piv)
print("OK rgetrf 1x8")
got = p_rpotrf(distribute(sp, m18, nb), gemm_backend="quire_exact").gather()
assert eq(got, decomp.rpotrf(sp, nb=nb, gemm_backend="quire_exact"))
print("OK rpotrf 1x8 quire_exact")
got = p_rpotrf(distribute(sp, m81, nb)).gather()
assert eq(got, decomp.rpotrf(sp, nb=nb))
print("OK rpotrf 8x1")
lu_d, piv_d = p_rgetrf(distribute(gp, m81, nb))
assert eq(lu_d.gather(), lu) and eq(piv_d, piv)
print("OK rgetrf 8x1")
print("DONE")
""", timeout=900)
    assert "DONE" in out


# --------------------------------------------------------------------------
# distributed iterative refinement
# --------------------------------------------------------------------------

@pytest.mark.multi_device
def test_p_rgesv_ir_matches_single_device(multi_device):
    """Distributed residuals (limb psum) + distributed LU must reproduce
    the single-device refined pair word-for-word — hence the exact same
    digits-gained on the backward-error protocol."""
    out = multi_device(_PRELUDE + """
mesh = make_grid_mesh(2, 2)
n, nb, nrhs = 96, 32, 2
g = rng.standard_normal((n, n))
x64 = rng.standard_normal((n, nrhs))
gp = P.from_float64(jnp.asarray(g))
bp = P.from_float64(jnp.asarray(g @ x64))
ad = distribute(gp, mesh, nb)

# the residual primitive itself
xp = P.from_float64(jnp.asarray(x64[:, 0]))
assert eq(p_residual_quire(ad, xp, bp[:, 0]),
          refine.residual_quire(gp, xp, bp[:, 0]))
print("OK residual")

(hi_d, lo_d), (lu_d, piv_d) = p_rgesv_ir(ad, bp, iters=2)
(hi_s, lo_s), (lu_s, piv_s) = refine.rgesv_ir(gp, bp, iters=2, nb=nb)
assert eq(hi_d, hi_s) and eq(lo_d, lo_s)
assert eq(lu_d.gather(), lu_s) and eq(piv_d, piv_s)
print("OK pair words")

# identical words => identical digits gained over the plain solve
# (column 0; the quire substitution sweeps take vector RHS)
a64 = np.asarray(P.to_float64(gp)); b64 = np.asarray(P.to_float64(bp[:, 0]))
from repro.lapack import solve as S
x_plain = np.asarray(P.to_float64(S.rgetrs(lu_s, piv_s, bp[:, 0],
                                           quire=True)))
x_ir = np.asarray(refine.pair_to_float64(hi_d[:, 0], lo_d[:, 0]))
def berr(x):
    r = b64 - a64 @ x
    return np.linalg.norm(r) / (np.linalg.norm(a64) * np.linalg.norm(x)
                                + np.linalg.norm(b64))
digits = np.log10(berr(x_plain) / berr(x_ir))
assert digits >= 2.0, digits
print("digits_gained %.2f" % digits)
print("DONE")
""", timeout=900)
    assert "DONE" in out


@pytest.mark.multi_device
def test_p_rposv_ir_matches_single_device(multi_device):
    out = multi_device(_PRELUDE + """
mesh = make_grid_mesh(2, 2)
n, nb = 96, 32
g = rng.standard_normal((n, n))
sp64 = g.T @ g + n * np.eye(n)
x64 = rng.standard_normal(n)
sp = P.from_float64(jnp.asarray(sp64))
bp = P.from_float64(jnp.asarray(sp64 @ x64))
(hi_d, lo_d), l_d = p_rposv_ir(distribute(sp, mesh, nb), bp, iters=2)
(hi_s, lo_s), l_s = refine.rposv_ir(sp, bp, iters=2, nb=nb)
assert eq(hi_d, hi_s) and eq(lo_d, lo_s) and eq(l_d.gather(), l_s)
print("DONE")
""", timeout=900)
    assert "DONE" in out
