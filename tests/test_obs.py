"""positscope (repro.obs) acceptance tests.

The contract under test, in order of importance:

1. **Zero-cost when disabled** — with no collector open (or with tracer
   inputs, i.e. the caller is being traced into an outer jit), the
   instrumented entry points dispatch the ORIGINAL jitted programs:
   lowered text is byte-identical and results are bit-identical.
2. **Bit-identical when enabled** — the collect-variant programs return
   the same words as the plain ones (telemetry is read-only).
3. **Histograms are right** — regime-width / scale histograms and
   golden-zone occupancy match an independent pure-Python bit-level
   oracle (tests/posit_oracle.py style, exact Fractions) on p32e2 /
   p16e1 / p8e2.
4. Spans nest, serialize to Chrome trace_event JSON, and round-trip.
5. The hlo_analysis dtype table covers the int64 limb planes (the s64
   regression) and the IR sweep series shows a contracting residual.
"""
from __future__ import annotations

import json
from fractions import Fraction

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import posit
from repro.core.formats import P8E2, P16E1, P32E2
from repro import obs
from repro.kernels import ops
from repro.lapack import decomp, qr, refine
from repro.launch import hlo_analysis

import posit_oracle


def _pm(rng, shape, fmt=P32E2, lo=-6, hi=6):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return posit.from_float64(jnp.asarray(x), fmt)


# --------------------------------------------------------------------------
# 1. zero-cost when disabled
# --------------------------------------------------------------------------

def test_disabled_lowering_identical():
    """Tracing the public wrapper into an outer jit lowers to the SAME
    text as the underlying jitted program — even with a collector open
    (tracer inputs disable the obs path at the Python level)."""
    a = _pm(np.random.default_rng(0), (32, 32))
    spd = ops.rgemm(a, a, trans_b=True)

    wrapped = jax.jit(lambda x: decomp.rgetrf(x, nb=16)).lower(a).as_text()
    direct = jax.jit(lambda x: decomp._rgetrf_jit(x, nb=16)
                     ).lower(a).as_text()
    assert wrapped == direct

    with obs.scoped():
        wrapped_open = jax.jit(
            lambda x: decomp.rgetrf(x, nb=16)).lower(a).as_text()
    assert wrapped_open == direct

    w2 = jax.jit(lambda x: decomp.rpotrf(x, nb=16)).lower(spd).as_text()
    d2 = jax.jit(lambda x: decomp._rpotrf_jit(x, nb=16)).lower(spd).as_text()
    assert w2 == d2

    w3 = jax.jit(lambda x: ops.rgemm(x, x)).lower(a).as_text()
    d3 = jax.jit(lambda x: ops._rgemm_jit(x, x)).lower(a).as_text()
    assert w3 == d3


def test_disabled_recorders_are_noops():
    assert not obs.enabled()
    obs.inc("x")                  # all must be safe with no collector
    obs.gauge("x", 1.0)
    obs.observe("x", 2.0)
    obs.record("x", a=1)
    with obs.span("nope"):
        pass
    # active() needs an open collector even for concrete inputs
    assert obs.active(jnp.zeros(3)) is False


# --------------------------------------------------------------------------
# 2. bit-identical when enabled
# --------------------------------------------------------------------------

def test_enabled_bit_identity():
    rng = np.random.default_rng(1)
    n = 48
    a64 = rng.standard_normal((n, n))
    ap = posit.from_float64(jnp.asarray(a64))
    sp = posit.from_float64(jnp.asarray(a64.T @ a64 + n * np.eye(n)))
    bp = posit.from_float64(jnp.asarray(rng.standard_normal((n, 2))))
    rect = posit.from_float64(jnp.asarray(rng.standard_normal((n, n // 2))))

    lu0 = decomp.rgetrf(ap, nb=16)
    l0 = decomp.rpotrf(sp, nb=16)
    qr0 = qr.rgeqrf(rect, nb=16)
    (hi0, lo0), _ = refine.rgesv_ir(ap, bp, iters=2, nb=16)
    g0 = ops.rgemm(ap, ap)
    with obs.scoped() as m:
        lu1 = decomp.rgetrf(ap, nb=16)
        l1 = decomp.rpotrf(sp, nb=16)
        qr1 = qr.rgeqrf(rect, nb=16)
        (hi1, lo1), _ = refine.rgesv_ir(ap, bp, iters=2, nb=16)
        g1 = ops.rgemm(ap, ap)
    for x, y in zip(jax.tree_util.tree_leaves((lu0, l0, qr0, hi0, lo0, g0)),
                    jax.tree_util.tree_leaves((lu1, l1, qr1, hi1, lo1, g1))):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    d = m.to_dict()
    # rgesv_ir factorizes through the observed rgetrf too -> 2 calls
    assert d["counters"]["rgetrf.calls"] == 2
    assert d["counters"]["rpotrf.calls"] == 1
    assert d["counters"]["rgeqrf.calls"] == 1
    assert len(d["series"]["rgetrf.step"]) == 6      # ceil(48/16) x 2 calls
    assert "rgemm.out.golden_zone" in d["gauges"]


# --------------------------------------------------------------------------
# 3. numerics vs the pure-Python oracle
# --------------------------------------------------------------------------

def _oracle_word_stats(pattern: int, nbits: int, es: int):
    """(is_zero, is_nar, reg_len, scale, golden) from first-principles
    bit parsing + exact Fractions — no shared code with repro.obs."""
    mask = (1 << nbits) - 1
    p = pattern & mask
    if p == 0:
        return True, False, 0, 0, False
    if p == 1 << (nbits - 1):
        return False, True, 0, 0, False
    if p >> (nbits - 1):
        p = (-p) & mask
    bits = [(p >> i) & 1 for i in range(nbits - 2, -1, -1)]
    r0 = bits[0]
    m = 1
    while m < len(bits) and bits[m] == r0:
        m += 1
    k = (m - 1) if r0 == 1 else -m
    reg_len = min(m + 1, nbits - 1)                  # run + terminator
    rest = bits[m + 1:] if m < len(bits) else []
    e = 0
    for b in rest[:es]:
        e = 2 * e + b
    e <<= es - len(rest[:es])
    scale = k * (1 << es) + e
    val = abs(posit_oracle.decode(pattern, nbits, es))
    lo = Fraction(2) ** -(1 << es)
    hi = Fraction(2) ** (1 << es)
    golden = lo <= val < hi
    assert golden == (k in (0, -1))                  # two defs, one zone
    return False, False, reg_len, scale, golden


@pytest.mark.parametrize("fmt", [P32E2, P16E1, P8E2],
                         ids=lambda f: f.name)
def test_collect_numerics_vs_oracle(fmt):
    rng = np.random.default_rng(7)
    if fmt.nbits <= 16:
        # every non-NaR pattern of the format
        half = 1 << (fmt.nbits - 1)
        words = np.arange(-half + 1, half, dtype=np.int64)
        words = rng.permutation(words)[:4096]
    else:
        x = rng.standard_normal(4096) * np.exp2(rng.uniform(-24, 24, 4096))
        words = np.asarray(posit.from_float64(jnp.asarray(x), fmt),
                           np.int64)
    st = obs.collect_numerics(jnp.asarray(words, jnp.int32), fmt)

    reg_hist: dict[int, int] = {}
    scale_hist: dict[int, int] = {}
    nz = nnar = ngold = nfin = 0
    reg_sum = 0
    for w in words:
        z, nar, reg_len, scale, golden = _oracle_word_stats(
            int(w), fmt.nbits, fmt.es)
        if z:
            nz += 1
            continue
        if nar:
            nnar += 1
            continue
        nfin += 1
        reg_sum += reg_len
        ngold += golden
        reg_hist[reg_len] = reg_hist.get(reg_len, 0) + 1
        scale_hist[scale] = scale_hist.get(scale, 0) + 1

    assert int(st["zero"]) == nz
    assert int(st["nar"]) == nnar
    got_reg = {i: int(v) for i, v in enumerate(np.asarray(st["regime_hist"]))
               if v}
    got_scale = {i - fmt.max_scale: int(v)
                 for i, v in enumerate(np.asarray(st["scale_hist"])) if v}
    assert got_reg == reg_hist
    assert got_scale == scale_hist
    assert float(st["golden_frac"]) == pytest.approx(ngold / max(nfin, 1))
    assert float(st["regime_mean"]) == pytest.approx(reg_sum / max(nfin, 1))


def test_golden_zone_bounds():
    assert obs.golden_zone_bounds(P32E2) == (1 / 16, 16.0)
    assert obs.golden_zone_bounds(P16E1) == (1 / 4, 4.0)
    assert obs.golden_zone_bounds(P8E2) == (1 / 16, 16.0)
    # exactly-at-bounds membership: lo is in, hi is out
    w = posit.from_float64(jnp.asarray([1 / 16, 15.9, 16.0, 0.05]), P32E2)
    assert obs.golden_zone_fraction(w, P32E2) == pytest.approx(0.5)


def test_encode_round_stats():
    # exactly-representable values round nowhere; 1/3 always rounds;
    # huge values saturate
    st = obs.encode_round_stats(jnp.asarray([1.0, 1.5, -2.25, 0.0]), P32E2)
    assert int(st["total"]) == 3                     # zero not counted
    assert int(st["rounded"]) == 0
    assert int(st["saturated"]) == 0
    st = obs.encode_round_stats(jnp.asarray([1 / 3, 1e300, 1e-300]), P32E2)
    assert int(st["rounded"]) == 1
    assert int(st["saturated"]) == 2


def test_log2_bucket():
    from repro.obs.metrics import ZERO_BUCKET, log2_bucket
    assert log2_bucket(1.0) == 0
    assert log2_bucket(0.5) == -1
    assert log2_bucket(3.0) == 1
    assert log2_bucket(-4.0) == 2
    assert log2_bucket(0.0) == ZERO_BUCKET
    assert log2_bucket(float("nan")) == ZERO_BUCKET


def test_quire_carry_stats():
    rng = np.random.default_rng(3)
    a = _pm(rng, (8, 64), lo=-2, hi=2)
    b = _pm(rng, (64, 8), lo=-2, hi=2)
    from repro.quire import quire_gemm_limbs
    limbs, _ = quire_gemm_limbs(a, b, P32E2)
    st = obs.quire_carry_stats(limbs)
    per = np.asarray(st["per_limb"])
    assert per.shape == (limbs.shape[-1],)
    assert int(st["total"]) == per.sum()
    assert int(st["total"]) > 0                      # deposits do carry
    assert int(obs.quire_carry_stats(jnp.zeros((4, 16), jnp.int64))
               ["total"]) == 0


# --------------------------------------------------------------------------
# 4. spans + chrome trace
# --------------------------------------------------------------------------

def test_span_nesting_and_chrome_roundtrip(tmp_path):
    with obs.scoped() as m:
        with obs.span("outer", size=3):
            with obs.span("inner"):
                pass
    names = {e["name"]: e for e in m.events}
    assert set(names) == {"outer", "inner"}
    assert names["inner"]["args"]["path"] == "outer.inner"
    assert names["inner"]["args"]["depth"] == 2
    assert names["outer"]["args"]["size"] == 3
    assert names["inner"]["ts"] >= names["outer"]["ts"]
    assert names["inner"]["dur"] <= names["outer"]["dur"]

    path = tmp_path / "trace.json"
    m.save_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"
        for key in ("ts", "dur", "pid", "tid", "name", "cat", "args"):
            assert key in ev


def test_scoped_nesting_and_json():
    with obs.scoped() as outer:
        obs.inc("n")
        with obs.scoped() as inner:
            obs.inc("n", 2)
        obs.inc("n")
    assert inner.counters["n"] == 2                  # only while open
    assert outer.counters["n"] == 4
    json.loads(outer.to_json())                      # JSON-clean


# --------------------------------------------------------------------------
# 5. IR sweep series + hlo_analysis regression
# --------------------------------------------------------------------------

def test_ir_sweep_series():
    rng = np.random.default_rng(5)
    n = 40
    a = rng.standard_normal((n, n)) + n * np.eye(n)
    b = rng.standard_normal(n)
    ap = posit.from_float64(jnp.asarray(a))
    bp = posit.from_float64(jnp.asarray(b))
    with obs.scoped() as m:
        refine.rgesv_ir(ap, bp, iters=3, nb=16)
    rows = m.to_dict()["series"]["ir.sweep"]
    assert [r["sweep"] for r in rows] == [0, 1, 2]
    norms = [r["r_norm"] for r in rows]
    assert norms[-1] < norms[0]                      # refinement contracts
    assert rows[-1]["digits_gained"] > 2
    assert all(isinstance(r["limb_carries"], int) for r in rows)


# Optimized-HLO lines as emitted by jaxlib's CPU SPMD partitioner for the
# k_split pdgemm / limb-psum programs (captured shapes): the limb planes
# are s64 — with s64 missing from the dtype table these counted 0 bytes.
_HLO_SNIPPET = """\
  %all-reduce.1 = s64[4,2,16]{2,1,0} all-reduce(s64[4,2,16]{2,1,0} %x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %all-reduce.2 = s32[4,2]{1,0} all-reduce(s32[4,2]{1,0} %n), replica_groups={{0,1},{2,3}}, to_apply=%add
  %reduce-scatter.1 = s64[32,16,16]{2,1,0} reduce-scatter(s64[32,32,16]{2,1,0} %l), dimensions={1}, to_apply=%add
  %all-gather.1 = c64[8,4]{1,0} all-gather(c64[2,4]{1,0} %g), dimensions={0}
"""


def test_collective_bytes_int64_and_complex():
    got = hlo_analysis.collective_bytes(_HLO_SNIPPET)
    assert got["all-reduce"] == 4 * 2 * 16 * 8 + 4 * 2 * 4
    assert got["reduce-scatter"] == 32 * 16 * 16 * 8
    assert got["all-gather"] == 8 * 4 * 8            # c64 is 8 bytes
    for dt in ("s64", "u64", "c64", "c128"):
        assert dt in hlo_analysis._BYTES


# --------------------------------------------------------------------------
# 6. distributed byte accounting (plan vs HLO vs runtime), 2x2 grid
# --------------------------------------------------------------------------

@pytest.mark.multi_device
def test_pdgemm_collective_accounting(multi_device):
    out = multi_device("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro import obs
        from repro.core import posit
        from repro.core.formats import P32E2
        from repro.dist import layout, pblas
        from repro.launch import hlo_analysis

        n, nb = 64, 16
        mesh = jax.make_mesh((2, 2), ("row", "col"))
        rng = np.random.default_rng(0)
        A = layout.distribute(posit.from_float64(
            jnp.asarray(rng.standard_normal((n, n)))), mesh, nb)
        B = layout.distribute(posit.from_float64(
            jnp.asarray(rng.standard_normal((n, n)))), mesh, nb)
        lay = A.layout
        c0 = jax.device_put(
            jnp.zeros((lay.p * lay.lm, lay.q * lay.ln), jnp.int32),
            jax.sharding.NamedSharding(mesh, pblas._SPEC))
        for k_split, backend in ((False, "xla_quire"),
                                 (True, "quire_exact")):
            plan = pblas.pdgemm_collective_plan(lay, lay, k_split=k_split)
            hlo = hlo_analysis.collective_bytes(pblas._pdgemm_sharded.lower(
                A.data, B.data, c0, lay_a=lay, lay_b=lay, mesh=mesh,
                alpha=1.0, beta=0.0, backend=backend, k_split=k_split,
                fmt=P32E2).compile().as_text())
            with obs.scoped() as m:
                pblas.pdgemm(A, B, backend=backend, k_split=k_split)
            pre = "dist.pdgemm."
            run = {k[len(pre):-len(".bytes")]: int(v)
                   for k, v in m.to_dict()["counters"].items()
                   if k.startswith(pre) and k.endswith(".bytes")}
            assert plan == hlo == run, (k_split, plan, hlo, run)
        # residual accounting: plan vs runtime counters
        x = posit.from_float64(jnp.asarray(rng.standard_normal(n)))
        b = posit.from_float64(jnp.asarray(rng.standard_normal(n)))
        with obs.scoped() as m:
            pblas.p_residual_quire(A, x, b, jnp.zeros_like(x))
        pre = "dist.p_residual."
        run = {k[len(pre):-len(".bytes")]: int(v)
               for k, v in m.to_dict()["counters"].items()
               if k.startswith(pre) and k.endswith(".bytes")}
        assert run == pblas.p_residual_plan(lay, 1)
        print("ACCOUNTING_OK")
    """)
    assert "ACCOUNTING_OK" in out
