"""Format-parametric stack: per-format oracle pinning, p32e2 bit-identity
vs PR 3, and the mixed-precision IR acceptance.

Three layers of guarantees:

1. **PR-3 golden pins** — sha256 of the posit words every p32e2 path
   produced BEFORE the format parameterization (captured from the PR-3
   tree on fixed seeds).  The refactor threads a static ``fmt`` whose
   constants fold at trace time, so every p32e2 word must be bit-identical
   — any hash change is a silent numerics change, not noise.
2. **Per-format oracle** — p16e1 and p8e2 encode/decode/round round-trips
   and the ``chain_round`` identity against the exact rational oracle
   (tests/posit_oracle.py), property-tested with hypothesis when
   installed and a deterministic fixed-seed sweep otherwise (same
   convention as test_posit_core.py).
3. **Mixed-precision acceptance** — ``rgesv_mp`` (p16e1 factor + p32e2
   quire refinement) reaches the same backward-error digits as
   ``rgesv_ir`` on the §5.1 sigma grid.
"""
import hashlib
from fractions import Fraction

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

import posit_oracle as oracle
from repro.core import posit as P
from repro.core.formats import P16E1, P32E2, P8E2, get_format
from repro.kernels.ops import rgemm
from repro.kernels.posit_gemm import (decode_split_f32, encode_p16_f32,
                                      encode_p32_f32, encode_posit_f32)
from repro.lapack import decomp, error_eval, refine, solve


# --------------------------------------------------------------------------
# 1. PR-3 golden pins: every p32e2 path bit-identical to the pre-refactor
#    tree (hashes captured from commit 59ee04b on these exact seeds)
# --------------------------------------------------------------------------

GOLDEN_P32 = {
    "rgemm_xla_quire": "7c1a480e5c9a7d8c",
    "rgemm_quire_exact": "7c1a480e5c9a7d8c",
    "rgemm_faithful": "7a55e20adb994b6a",
    "rgemm_pallas_split3": "3fd3e072ff75b648",
    "rgemm_ab1": "e0d80ac10820c8d9",
    "rpotrf": "7e9165ec6ef12151",
    "rgetrf": "07c2e4fd338ae084",
    "rgetrs_q": "895d2a22713a1d75",
    "rgesv_ir": "d16b0c99d17ea97f",
    "rposv_ir": "42dd7e9cbf36c6c2",
    "residual": "36651c97a763a809",
}


def _h(*arrs):
    m = hashlib.sha256()
    for a in arrs:
        m.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return m.hexdigest()[:16]


@pytest.fixture(scope="module")
def golden_inputs():
    rng = np.random.default_rng(42)
    a64 = rng.standard_normal((48, 48))
    s64 = a64.T @ a64
    b64 = rng.standard_normal(48)
    return (P.from_float64(jnp.asarray(a64)),
            P.from_float64(jnp.asarray(s64)),
            P.from_float64(jnp.asarray(b64)))


def test_golden_rgemm_backends(golden_inputs):
    ap, sp, _ = golden_inputs
    for bk in ("xla_quire", "quire_exact", "faithful", "pallas_split3"):
        assert _h(rgemm(ap, ap, backend=bk)) == GOLDEN_P32[f"rgemm_{bk}"], bk
    got = rgemm(ap, ap, sp, alpha=-1.0, beta=1.0, backend="quire_exact")
    assert _h(got) == GOLDEN_P32["rgemm_ab1"]


def test_golden_factorizations_and_solves(golden_inputs):
    ap, sp, bp = golden_inputs
    assert _h(decomp.rpotrf(sp, nb=16)) == GOLDEN_P32["rpotrf"]
    lu, piv = decomp.rgetrf(ap, nb=16)
    assert _h(lu, piv) == GOLDEN_P32["rgetrf"]
    assert _h(solve.rgetrs(lu, piv, bp, quire=True)) == GOLDEN_P32["rgetrs_q"]


def test_golden_refinement(golden_inputs):
    ap, sp, bp = golden_inputs
    (xh, xl), _ = refine.rgesv_ir(ap, bp, iters=2, nb=16)
    assert _h(xh, xl) == GOLDEN_P32["rgesv_ir"]
    (yh, yl), _ = refine.rposv_ir(sp, bp, iters=2, nb=16)
    assert _h(yh, yl) == GOLDEN_P32["rposv_ir"]
    assert _h(refine.residual_quire(ap, xh, bp, xl)) == GOLDEN_P32["residual"]


# --------------------------------------------------------------------------
# 2. per-format oracle: encode/decode/round round-trip + chain_round
#    identity (p16e1, p8e2), hypothesis-or-deterministic
# --------------------------------------------------------------------------

def test_p8e2_exhaustive_decode_and_roundtrip():
    all_p = np.arange(-127, 128, dtype=np.int32)
    got = np.asarray(P.to_float64(all_p, P8E2))
    want = np.array([float(oracle.decode(int(p), 8, 2)) for p in all_p])
    assert np.array_equal(got, want)
    # decode -> encode round-trip is the identity on every pattern
    back = np.asarray(P.from_float64(got, P8E2))
    assert np.array_equal(back, all_p)


def test_p16e1_sampled_roundtrip():
    rng = np.random.default_rng(11)
    ps = rng.integers(-(1 << 15) + 1, 1 << 15, size=4000).astype(np.int32)
    vals = np.asarray(P.to_float64(ps, P16E1))
    want = np.array([float(oracle.decode(int(p), 16, 1)) for p in ps[:400]])
    assert np.array_equal(vals[:400], want)
    back = np.asarray(P.from_float64(vals, P16E1))
    assert np.array_equal(back, ps)


def _check_round_nearest(x: float, fmt):
    """from_float64 must equal the oracle's round-to-nearest pattern."""
    got = int(np.asarray(P.from_float64(np.array([x], np.float64), fmt))[0])
    want = oracle.encode(Fraction(x) if x else Fraction(0), fmt.nbits,
                         fmt.es)
    assert got == want, (fmt.name, x)


def _check_chain_round_identity(x: float, fmt):
    """chain_round == to_float64(from_float64(x)) — the fused-chain
    contract the panel kernels rely on, per format."""
    via_word = float(np.asarray(
        P.to_float64(P.from_float64(np.array([x], np.float64), fmt), fmt))[0])
    direct = float(np.asarray(P.chain_round(np.array([x], np.float64),
                                            fmt))[0])
    assert via_word == direct or (np.isnan(via_word) and np.isnan(direct)), (
        fmt.name, x, via_word, direct)


_FMTS = (P16E1, P8E2)

if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=-1e12, max_value=1e12, allow_nan=False,
                       allow_infinity=False, allow_subnormal=False)

    @settings(max_examples=120, deadline=None)
    @given(finite, st.sampled_from(_FMTS))
    def test_round_nearest_matches_oracle(x, fmt):
        _check_round_nearest(x, fmt)

    @settings(max_examples=120, deadline=None)
    @given(finite, st.sampled_from(_FMTS))
    def test_chain_round_identity(x, fmt):
        _check_chain_round_identity(x, fmt)

else:
    # deterministic fallback: fixed-seed magnitudes + hand-picked edges so
    # the per-format pinning still runs where hypothesis isn't installed
    _RNG = np.random.default_rng(20260727)
    _XS = list(_RNG.standard_normal(80) * np.exp2(_RNG.uniform(-30, 30, 80)))
    _XS += [0.0, 1.0, -1.0, 0.75, 1.5, 2.0 ** 24, 2.0 ** -24, 1e12, -1e12,
            2.0 ** -28, 3.0, -3.0]

    def test_round_nearest_matches_oracle():
        for fmt in _FMTS:
            for x in _XS:
                _check_round_nearest(float(x), fmt)

    def test_chain_round_identity():
        for fmt in _FMTS:
            for x in _XS:
                _check_chain_round_identity(float(x), fmt)


def test_chain_round_fixpoint_on_lattice():
    """chain_round is the identity on every posit value (p8e2 exhaustive,
    p16e1 sampled) — no double rounding in the fused-chain panels."""
    vals8 = np.asarray(P.to_float64(np.arange(-127, 128, dtype=np.int32),
                                    P8E2))
    assert np.array_equal(np.asarray(P.chain_round(vals8, P8E2)), vals8)
    rng = np.random.default_rng(13)
    p16 = rng.integers(-(1 << 15) + 1, 1 << 15, size=3000).astype(np.int32)
    vals16 = np.asarray(P.to_float64(p16, P16E1))
    assert np.array_equal(np.asarray(P.chain_round(vals16, P16E1)), vals16)


def test_pconvert_round_trips_and_rounds():
    """Widening p16e1 -> p32e2 is exact (round-trips); narrowing is the
    correctly-rounded oracle encode of the exact wide value."""
    rng = np.random.default_rng(17)
    p16 = rng.integers(-(1 << 15) + 1, 1 << 15, size=2000).astype(np.int32)
    wide = P.pconvert(p16, P16E1, P32E2)
    back = np.asarray(P.pconvert(wide, P32E2, P16E1))
    assert np.array_equal(back, p16)
    p32 = rng.integers(-(1 << 31) + 1, 1 << 31, size=300).astype(np.int32)
    narrow = np.asarray(P.pconvert(p32, P32E2, P16E1))
    for p, g in zip(p32, narrow):
        want = oracle.encode(oracle.decode(int(p), 32, 2), 16, 1)
        assert int(g) == want, int(p)


def test_get_format_registry():
    assert get_format("p8e2") is P8E2
    assert get_format("p16e1") is P16E1
    with pytest.raises(KeyError):
        get_format("p64e3")


# --------------------------------------------------------------------------
# kernel codecs per format: in-kernel encode == from_float32_bits; decode
# split is exact
# --------------------------------------------------------------------------

def test_encode_pXX_f32_matches_bit_codec():
    rng = np.random.default_rng(19)
    x = (rng.standard_normal(30000) * np.exp2(rng.uniform(-40, 40, 30000))
         ).astype(np.float32)
    x = np.concatenate([x, np.array([0.0, 1.0, -1.0, np.inf, -np.inf,
                                     np.nan, 3.3e38, 1e-45], np.float32)])
    for fmt, enc in ((P32E2, encode_p32_f32), (P16E1, encode_p16_f32),
                     (P8E2, lambda v: encode_posit_f32(v, P8E2))):
        got = np.asarray(enc(jnp.asarray(x)))
        want = np.asarray(P.from_float32_bits(x, fmt))
        assert np.array_equal(got, want), fmt.name


def test_decode_split_f32_exact_per_format():
    rng = np.random.default_rng(23)
    for fmt in (P32E2, P16E1, P8E2):
        half = 1 << (fmt.nbits - 1)
        ps = rng.integers(-half + 1, half, 8000).astype(np.int32)
        hi, lo = decode_split_f32(jnp.asarray(ps), fmt)
        got = np.asarray(hi, np.float64) + np.asarray(lo, np.float64)
        want = np.asarray(P.to_float64(ps, fmt))
        big = np.abs(want) >= 2.0 ** -99
        assert np.array_equal(got[big], want[big]), fmt.name
        assert np.isnan(got[np.isnan(want)]).all(), fmt.name


# --------------------------------------------------------------------------
# format-parametric LAPACK: backends agree per format; p16e1 factorization
# reconstructs
# --------------------------------------------------------------------------

def test_rgemm_backends_agree_p16e1():
    """quire_exact == xla_quire words in p16e1 (both are exact-sum, one
    rounding); pallas fused epilogue agrees too (13-bit significands are
    f32-exact, so the f32 accumulator path is also a single rounding of
    an exact sum for small K)."""
    rng = np.random.default_rng(29)
    a = P.from_float64(jnp.asarray(rng.standard_normal((24, 24))), P16E1)
    b = P.from_float64(jnp.asarray(rng.standard_normal((24, 24))), P16E1)
    ref = np.asarray(rgemm(a, b, backend="quire_exact", fmt=P16E1))
    xla = np.asarray(rgemm(a, b, backend="xla_quire", fmt=P16E1))
    assert np.array_equal(ref, xla)
    pal = np.asarray(rgemm(a, b, backend="pallas_split3", block=8,
                           fmt=P16E1))
    truth = (np.asarray(P.to_float64(a, P16E1))
             @ np.asarray(P.to_float64(b, P16E1)))
    err = np.abs(np.asarray(P.to_float64(pal, P16E1)) - truth).max()
    assert err < 1e-2 * np.abs(truth).max()


def test_rpotrf_rgetrf_p16e1_reconstruct():
    rng = np.random.default_rng(31)
    n = 32
    x = rng.standard_normal((n, n))
    a64 = x.T @ x + n * np.eye(n)
    ap = P.from_float64(jnp.asarray(a64), P16E1)
    lp = decomp.rpotrf(ap, nb=16, fmt=P16E1)
    lv = np.asarray(P.to_float64(lp, P16E1))
    rec = lv @ lv.T
    a16 = np.asarray(P.to_float64(ap, P16E1))
    assert np.linalg.norm(rec - a16) / np.linalg.norm(a16) < 1e-2

    g64 = rng.standard_normal((n, n))
    gp = P.from_float64(jnp.asarray(g64), P16E1)
    lup, ipiv = decomp.rgetrf(gp, nb=16, fmt=P16E1)
    luv = np.asarray(P.to_float64(lup, P16E1))
    lm = np.tril(luv, -1) + np.eye(n)
    um = np.triu(luv)
    g16 = np.asarray(P.to_float64(gp, P16E1))
    pa = g16.copy()
    for kk, pv in enumerate(np.asarray(ipiv)):
        pa[[kk, pv], :] = pa[[pv, kk], :]
    assert np.linalg.norm(lm @ um - pa) / np.linalg.norm(pa) < 1e-2


def test_backward_error_study_runs_per_format():
    """The §5.1 protocol runs end-to-end in narrower formats; p16e1 loses
    digits to binary32 (expected — 12-bit fractions), and the p32e2 cell
    matches the default-format cell exactly."""
    r16 = error_eval.backward_error_study(32, 1.0, "lu", nb=16,
                                          gemm_backend="xla_quire",
                                          fmt=P16E1)
    assert r16.fmt == "p16e1" and r16.e_posit > r16.e_binary32
    r32 = error_eval.backward_error_study(32, 1.0, "lu", nb=16,
                                          gemm_backend="xla_quire")
    r32b = error_eval.backward_error_study(32, 1.0, "lu", nb=16,
                                           gemm_backend="xla_quire",
                                           fmt=P32E2)
    assert r32.e_posit == r32b.e_posit


# --------------------------------------------------------------------------
# 3. mixed-precision IR acceptance: rgesv_mp digits == rgesv_ir digits on
#    the §5.1 sigma grid
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sigma", [1e-2, 1.0, 1e2])
def test_rgesv_mp_matches_ir_digits(sigma):
    r = error_eval.mixed_precision_study(48, sigma, "lu", nb=16)
    # same floor: within half a decimal digit of the full-width IR solve
    assert r.digits_lost < 0.5, r


def test_rposv_mp_matches_ir_digits():
    r = error_eval.mixed_precision_study(48, 1.0, "cholesky", nb=16)
    assert r.digits_lost < 0.5, r


def test_rgesv_mp_multi_rhs_and_factor_format():
    """Multi-RHS vmap convention + the returned factors really are p16e1
    words (the narrow factorization is what the speedup is made of)."""
    rng = np.random.default_rng(37)
    n = 32
    a64 = rng.standard_normal((n, n))
    b64 = rng.standard_normal((n, 3))
    ap = P.from_float64(jnp.asarray(a64))
    bp = P.from_float64(jnp.asarray(b64))
    (xh, xl), (lu, ipiv) = refine.rgesv_mp(ap, bp, iters=8, nb=16)
    assert xh.shape == (n, 3) and lu.shape == (n, n)
    # p16e1 words live in [-2^15, 2^15): narrow patterns, wide int32 would
    # exceed this range almost surely for a 32x32 factor
    assert np.abs(np.asarray(lu)).max() < (1 << 15)
    x = np.asarray(refine.pair_to_float64(xh, xl))
    want = np.linalg.solve(np.asarray(P.to_float64(ap)),
                           np.asarray(P.to_float64(bp)))
    assert np.abs(x - want).max() / np.abs(want).max() < 1e-10
