"""PR-2 fast paths vs their pre-refactor baselines.

Every optimization in this PR is a *schedule* change (chunked integer
limb adds, fused single-dispatch drivers, decode-once chains, in-kernel
encode), so the contract everywhere is BIT-IDENTITY, not tolerance —
except the cross-backend rgemm parity block, where f32 accumulation is
compared against the exact quire with the kernel's analytic error bound.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import posit as P
from repro.core.formats import P16E1, P32E2
from repro import quire as Q
from repro.kernels.ops import rgemm
from repro.kernels.posit_gemm import (encode_p32_f32, posit_gemm,
                                      posit_gemm_f32)
from repro.lapack import decomp
from repro.lapack.blas import rtrsm_left_lower, rtrsm_right_lowerT


def _posits(rng, shape, lo=-8, hi=8, fmt=P32E2):
    x = rng.standard_normal(shape) * np.exp2(rng.uniform(lo, hi, shape))
    return P.from_float64(jnp.asarray(x), fmt)


# --------------------------------------------------------------------------
# K-chunked quire GEMM / quire_dot: any schedule is bit-identical
# --------------------------------------------------------------------------

def test_quire_gemm_chunking_bit_identical():
    rng = np.random.default_rng(0)
    for (m, k, n) in ((7, 33, 5), (16, 64, 16), (3, 100, 9)):
        ap = _posits(rng, (m, k), -30, 30)
        bp = _posits(rng, (k, n), -30, 30)
        cp = _posits(rng, (m, n))
        ref = np.asarray(Q.quire_gemm(ap, bp, cp, negate=True,
                                      kc=1, unroll=1))
        for kc, ur in ((4, 1), (8, 4), (16, 2), (64, 1)):
            got = np.asarray(Q.quire_gemm(ap, bp, cp, negate=True,
                                          kc=kc, unroll=ur))
            assert np.array_equal(ref, got), (m, k, n, kc, ur)


def test_quire_dot_chunking_bit_identical():
    rng = np.random.default_rng(1)
    for fmt in (P32E2, P16E1):
        ap = _posits(rng, (4, 300), -20, 20, fmt)
        bp = _posits(rng, (4, 300), -20, 20, fmt)
        ip = _posits(rng, (4,), fmt=fmt)
        ref = np.asarray(Q.quire_dot(ap, bp, fmt, init_p=ip, negate=True,
                                     kc=300))
        for kc in (7, 64, 128, None):
            got = np.asarray(Q.quire_dot(ap, bp, fmt, init_p=ip,
                                         negate=True, kc=kc))
            assert np.array_equal(ref, got), (fmt.name, kc)


# --------------------------------------------------------------------------
# rgemm backend parity: non-square, non-block-multiple shapes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas_split3", "pallas_split3_comp",
                                     "xla_quire"])
@pytest.mark.parametrize("shape", [(65, 17, 130), (33, 65, 9)])
def test_rgemm_backend_parity_odd_shapes(backend, shape):
    """Every accumulation backend agrees with the exact quire to the f32
    kernel's analytic bound on shapes that exercise padding/slicing."""
    m, k, n = shape
    rng = np.random.default_rng(2)
    ap = _posits(rng, (m, k), -4, 4)
    bp = _posits(rng, (k, n), -4, 4)
    exact = P.to_float64(rgemm(ap, bp, backend="quire_exact"))
    got = P.to_float64(rgemm(ap, bp, backend=backend, block=64))
    av = np.asarray(P.to_float64(ap))
    bv = np.asarray(P.to_float64(bp))
    scale = np.outer(np.linalg.norm(av, axis=1), np.linalg.norm(bv, axis=0))
    err = np.abs(np.asarray(got) - np.asarray(exact)) / np.maximum(scale,
                                                                   1e-300)
    assert err.max() < np.sqrt(k) * 8e-8, (backend, shape, err.max())


@pytest.mark.parametrize("backend", ["pallas_split3", "pallas_split3_comp",
                                     "xla_quire"])
def test_rgemm_backend_parity_trailing_update(backend):
    """alpha=-1/beta=1 — the factorizations' trailing-update form."""
    m, k, n = 65, 130, 17
    rng = np.random.default_rng(3)
    ap = _posits(rng, (m, k), -2, 2)
    bp = _posits(rng, (k, n), -2, 2)
    cp = _posits(rng, (m, n), -2, 2)
    exact = np.asarray(P.to_float64(rgemm(ap, bp, cp, alpha=-1.0, beta=1.0,
                                          backend="quire_exact")))
    got = np.asarray(P.to_float64(rgemm(ap, bp, cp, alpha=-1.0, beta=1.0,
                                        backend=backend, block=64)))
    av = np.asarray(P.to_float64(ap))
    bv = np.asarray(P.to_float64(bp))
    cv = np.asarray(P.to_float64(cp))
    scale = (np.outer(np.linalg.norm(av, axis=1),
                      np.linalg.norm(bv, axis=0)) + np.abs(cv))
    err = np.abs(got - exact) / np.maximum(scale, 1e-300)
    assert err.max() < np.sqrt(k) * 8e-8, (backend, err.max())


# --------------------------------------------------------------------------
# fused in-kernel posit encode
# --------------------------------------------------------------------------

def test_encode_p32_f32_matches_from_float32_bits():
    rng = np.random.default_rng(4)
    x = (rng.standard_normal(100000)
         * np.exp2(rng.uniform(-148, 130, 100000))).astype(np.float32)
    specials = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1e-45,
                         -1e-45, 2.0 ** -126, 2.0 ** 119, 2.0 ** -120,
                         1.5 * 2.0 ** 119, 3.4e38], np.float32)
    # every f32 exponent x mantissa corners, both signs
    exps = np.arange(0, 256, dtype=np.int64)
    mans = np.array([0, 1, 0x400000, 0x7FFFFF, 0x2AAAAA], np.int64)
    bits = ((exps[:, None] << 23) | mans[None, :]).reshape(-1)
    bits = bits.astype(np.uint32)
    corners = np.concatenate([bits, bits | np.uint32(1 << 31)]
                             ).view(np.float32)
    x = np.concatenate([x, specials, corners])
    got = np.asarray(encode_p32_f32(jnp.asarray(x)))
    want = np.asarray(P.from_float32_bits(jnp.asarray(x)))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("mode", ["split3", "split3_comp"])
def test_posit_gemm_fused_encode_bit_identical(mode):
    rng = np.random.default_rng(5)
    ap = _posits(rng, (128, 128), -6, 6)
    bp = _posits(rng, (128, 128), -6, 6)
    acc = posit_gemm_f32(ap, bp, mode=mode)
    for neg in (False, True):
        fused = np.asarray(posit_gemm(ap, bp, mode=mode, negate=neg))
        host = np.asarray(P.from_float32_bits(-acc if neg else acc))
        assert np.array_equal(fused, host), (mode, neg)


def test_rgemm_pallas_fused_matches_legacy_epilogue():
    """The fused path must equal the pre-refactor f32->f64->encode chain."""
    rng = np.random.default_rng(6)
    ap = _posits(rng, (40, 50), -4, 4)
    bp = _posits(rng, (50, 30), -4, 4)
    new = np.asarray(rgemm(ap, bp, backend="pallas_split3", block=64))
    ap_pad = jnp.pad(ap, ((0, 24), (0, 14)))
    bp_pad = jnp.pad(bp, ((0, 14), (0, 34)))
    acc = np.asarray(posit_gemm_f32(ap_pad, bp_pad, bm=64, bn=64, bk=64),
                     np.float64)[:40, :30]
    old = np.asarray(P.from_float64(jnp.asarray(acc)))
    assert np.array_equal(new, old)


# --------------------------------------------------------------------------
# fused-chain scalar ops and panels
# --------------------------------------------------------------------------

def test_chain_round_matches_word_roundtrip():
    rng = np.random.default_rng(7)
    for fmt in (P32E2, P16E1):
        x = rng.standard_normal(50000) * np.exp2(rng.uniform(-140, 140,
                                                             50000))
        x = np.concatenate([x, [0.0, -0.0, np.inf, -np.inf, np.nan,
                                5e-324, 2.0 ** 120, 2.0 ** -120,
                                1.5 * 2.0 ** 113, 2.0 ** 113]])
        got = np.asarray(P.chain_round(jnp.asarray(x), fmt))
        want = np.asarray(P.to_float64(P.from_float64(jnp.asarray(x), fmt),
                                       fmt))
        ok = (got == want) | (np.isnan(got) & np.isnan(want))
        assert ok.all(), (fmt.name, x[~ok][:5], got[~ok][:5], want[~ok][:5])


def test_chain_panels_match_legacy_panels():
    rng = np.random.default_rng(8)
    x = rng.standard_normal((48, 48))
    sp = P.from_float64(jnp.asarray(x.T @ x))
    assert np.array_equal(np.asarray(decomp.potf2(sp)),
                          np.asarray(decomp._potf2_words(sp)))
    g = rng.standard_normal((64, 24)) * np.exp2(rng.uniform(-6, 6, (64, 24)))
    gp = P.from_float64(jnp.asarray(g))
    pn, ivn = decomp.getf2(gp, 24)
    po, ivo = decomp._getf2_words(gp, 24)
    assert np.array_equal(np.asarray(pn), np.asarray(po))
    assert np.array_equal(np.asarray(ivn), np.asarray(ivo))


def test_chain_trsm_matches_word_domain():
    """Pin the chain-form triangular solves against a per-op word-domain
    reference (the pre-PR-2 semantics, reconstructed inline)."""
    def mul(a, b):
        return P.mul(a, b, P32E2, backend="fast")

    def sub(a, b):
        return P.sub(a, b, P32E2, backend="fast")

    def div(a, b):
        return P.div(a, b, P32E2, backend="fast")

    rng = np.random.default_rng(9)
    n, m = 24, 8
    l64 = np.tril(rng.standard_normal((n, n))) + 4 * np.eye(n)
    b64 = rng.standard_normal((n, m))
    lp = P.from_float64(jnp.asarray(l64))
    bp = P.from_float64(jnp.asarray(b64))

    # word-domain rtrsm_left_lower (unit_diag=False), PR-1 op order
    bw = np.asarray(bp).copy()
    lw = np.asarray(lp)
    for k in range(n):
        xk = np.asarray(div(jnp.asarray(bw[k]), jnp.asarray(lw[k, k])))
        upd = np.asarray(sub(jnp.asarray(bw),
                             mul(jnp.asarray(lw[:, k][:, None]),
                                 jnp.asarray(xk[None, :]))))
        bw[k + 1:, :] = upd[k + 1:, :]
        bw[k, :] = xk
    got = np.asarray(rtrsm_left_lower(lp, bp, unit_diag=False))
    assert np.array_equal(got, bw)

    # word-domain rtrsm_right_lowerT
    l11 = P.from_float64(jnp.asarray(
        np.tril(rng.standard_normal((m, m))) + 4 * np.eye(m)))
    b2 = P.from_float64(jnp.asarray(rng.standard_normal((n, m))))
    bw = np.asarray(b2).copy()
    lw = np.asarray(l11)
    for k in range(m):
        xk = np.asarray(div(jnp.asarray(bw[:, k]), jnp.asarray(lw[k, k])))
        upd = np.asarray(sub(jnp.asarray(bw),
                             mul(jnp.asarray(xk[:, None]),
                                 jnp.asarray(lw[:, k][None, :]))))
        bw[:, k + 1:] = upd[:, k + 1:]
        bw[:, k] = xk
    got = np.asarray(rtrsm_right_lowerT(b2, l11))
    assert np.array_equal(got, bw)


# --------------------------------------------------------------------------
# beta = 0 never references C (BLAS convention) on non-faithful backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas_split3", "xla_quire",
                                     "quire_exact"])
def test_rgemm_beta_zero_ignores_nar_in_c(backend):
    rng = np.random.default_rng(12)
    ap = _posits(rng, (8, 8))
    bp = _posits(rng, (8, 8))
    c_nar = jnp.full((8, 8), P32E2.nar_pattern, jnp.int32)
    got = np.asarray(rgemm(ap, bp, c_nar, beta=0.0, backend=backend,
                           block=64))
    ref = np.asarray(rgemm(ap, bp, backend=backend, block=64))
    assert np.array_equal(got, ref), backend
    assert not (got == P32E2.nar_pattern).any()


# --------------------------------------------------------------------------
# single-dispatch + batched drivers
# --------------------------------------------------------------------------

def test_single_dispatch_matches_loop_drivers():
    rng = np.random.default_rng(10)
    n = 96
    a64 = rng.standard_normal((n, n))
    ap = P.from_float64(jnp.asarray(a64))
    sp = P.from_float64(jnp.asarray(a64.T @ a64))
    lu_j, iv_j = decomp.rgetrf(ap, nb=32)
    lu_l, iv_l = decomp.rgetrf_loop(ap, nb=32)
    assert np.array_equal(np.asarray(lu_j), np.asarray(lu_l))
    assert np.array_equal(np.asarray(iv_j), np.asarray(iv_l))
    assert np.array_equal(np.asarray(decomp.rpotrf(sp, nb=32)),
                          np.asarray(decomp.rpotrf_loop(sp, nb=32)))


def test_ensemble_matches_study_same_backend():
    """backward_error_ensemble's POSIT cells == backward_error_study with
    the same gemm_backend (vmapping the posit programs changes no
    rounding).  The binary32 baseline is only compared loosely: XLA's
    batched f32 LU/Cholesky kernels round differently than the
    single-matrix forms."""
    from repro.lapack.error_eval import (backward_error_ensemble,
                                         backward_error_study)
    for algo in ("lu", "cholesky"):
        cells = backward_error_ensemble(32, [1.0, 100.0], algo=algo,
                                        seeds=(0,), nb=16,
                                        gemm_backend="xla_quire")
        for cell in cells:
            single = backward_error_study(32, cell.sigma, algo, seed=0,
                                          nb=16, gemm_backend="xla_quire")
            assert cell.e_posit == single.e_posit, (algo, cell.sigma)
            assert np.isclose(np.log10(cell.e_binary32),
                              np.log10(single.e_binary32), atol=1.0)


def test_batched_matches_single_bit_for_bit():
    rng = np.random.default_rng(11)
    n, batch = 48, 3
    mats = [rng.standard_normal((n, n)) for _ in range(batch)]
    gen = jnp.stack([P.from_float64(jnp.asarray(m)) for m in mats])
    spd = jnp.stack([P.from_float64(jnp.asarray(m.T @ m)) for m in mats])

    lub, ivb = decomp.rgetrf_batched(gen, nb=16)
    lb = decomp.rpotrf_batched(spd, nb=16)
    for i in range(batch):
        lu_i, iv_i = decomp.rgetrf(gen[i], nb=16)
        assert np.array_equal(np.asarray(lub[i]), np.asarray(lu_i)), i
        assert np.array_equal(np.asarray(ivb[i]), np.asarray(iv_i)), i
        assert np.array_equal(np.asarray(lb[i]),
                              np.asarray(decomp.rpotrf(spd[i], nb=16))), i
