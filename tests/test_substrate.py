"""Substrate tests: optimizer (+posit16 moments), checkpoint fault
tolerance, data determinism, serving engine, policy quantization,
compressed collectives."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.configs.shapes import ShapeCell
from repro.core.policy import decode_tensor, encode_tensor, quantize
from repro.data.pipeline import make_batch, input_specs
from repro.models import init_params
from repro.optim import adamw_init, adamw_update


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def _quad_problem():
    w = {"a": jnp.asarray(np.full((64,), 5.0, np.float32)),
         "b": jnp.asarray(np.full((8, 8), -3.0, np.float32))}
    def loss(p):
        return jnp.sum(p["a"] ** 2) + jnp.sum(p["b"] ** 2)
    return w, loss


def test_adamw_descends():
    w, loss = _quad_problem()
    opt = adamw_init(w)
    l0 = float(loss(w))
    for _ in range(60):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(w, opt, g, lr=0.1, wd=0.0)
    assert float(loss(w)) < 0.2 * l0


def test_adamw_posit16_moments_track_f32():
    w, loss = _quad_problem()
    w2 = jax.tree.map(jnp.copy, w)   # donation-safe copy
    o1 = adamw_init(w, compress_moments=False)
    o2 = adamw_init(w2, compress_moments=True)
    # compressed moments are int16 wire words
    m_leaf = jax.tree.leaves(o2["moments"])[0]
    assert m_leaf.dtype == jnp.int16
    for _ in range(30):
        g1 = jax.grad(loss)(w)
        g2 = jax.grad(loss)(w2)
        w, o1, _ = adamw_update(w, o1, g1, lr=0.05, wd=0.0)
        w2, o2, _ = adamw_update(w2, o2, g2, lr=0.05, wd=0.0,
                                 compress_moments=True)
    a1 = np.asarray(w["a"])
    a2 = np.asarray(w2["a"])
    assert np.abs(a1 - a2).max() < 0.05 * np.abs(a1).max() + 1e-2


# --------------------------------------------------------------------------
# checkpoint / fault tolerance
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("qwen2-0.5b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, (params, opt), extra={"note": "x"})
    assert latest_step(d) == 7
    (p2, o2), step, extra = restore_checkpoint(d, (params, opt))
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_integrity_detection(tmp_path):
    cfg = get_smoke_config("qwen2-0.5b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "ckpt")
    path = save_checkpoint(d, 1, params)
    # corrupt one shard
    victim = os.path.join(path, "leaf_00000.npy")
    with open(victim, "r+b") as f:
        f.seek(128)
        f.write(b"\xde\xad")
    try:
        restore_checkpoint(d, params)
        raise AssertionError("corruption not detected")
    except (IOError, ValueError):
        pass


def test_restart_reproduces_training(tmp_path):
    """Fault tolerance e2e: 6 straight steps == 3 steps + crash + resume."""
    from repro.launch.train import run
    d1 = str(tmp_path / "a")
    _, _, losses_straight = run("qwen2-0.5b", steps=6, batch=2, seq=16,
                                ckpt_dir=d1, ckpt_every=3)
    d2 = str(tmp_path / "b")
    run("qwen2-0.5b", steps=3, batch=2, seq=16, ckpt_dir=d2, ckpt_every=3)
    _, _, resumed = run("qwen2-0.5b", steps=6, batch=2, seq=16,
                        ckpt_dir=d2, ckpt_every=3)
    np.testing.assert_allclose(losses_straight[3:], resumed, rtol=1e-5)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_step_dependent():
    cfg = get_smoke_config("qwen2-0.5b")
    cell = ShapeCell("t", "train", 64, 4)
    b1 = make_batch(cfg, cell, step=5, seed=1)
    b2 = make_batch(cfg, cell, step=5, seed=1)
    b3 = make_batch(cfg, cell, step=6, seed=1)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert int(b1["tokens"].max()) < cfg.vocab
    # targets are next-token shifted
    assert np.array_equal(np.asarray(b1["tokens"])[:, 1:],
                          np.asarray(b1["targets"])[:, :-1])


def test_input_specs_cover_all_inputs():
    for arch in ("whisper-tiny", "internvl2-26b", "qwen2-0.5b"):
        cfg = get_smoke_config(arch)
        tr = input_specs(cfg, ShapeCell("t", "train", 64, 4))
        assert "tokens" in tr and "targets" in tr
        if cfg.family == "encdec":
            assert "frames" in tr
        if cfg.family == "vlm":
            assert "vis" in tr
        de = input_specs(cfg, ShapeCell("d", "decode", 64, 4))
        assert de["tokens"].shape == (4, 1) and de["pos"].shape == ()


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------

def test_generate_greedy():
    from repro.serving import generate
    cfg = get_smoke_config("qwen2-0.5b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    out = generate(params, cfg, prompts, max_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()


# --------------------------------------------------------------------------
# policy / codecs
# --------------------------------------------------------------------------

def test_quantize_idempotent_and_straight_through():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(512),
                    jnp.float32)
    q1 = quantize(x, "p16e1")
    q2 = quantize(q1, "p16e1")
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    g = jax.grad(lambda v: jnp.sum(quantize(v, "p16e1") ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(q1), rtol=1e-5)


def test_wire_codec_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(4096).astype(np.float32)
    p = encode_tensor(x, "p16e1")
    assert p.dtype == jnp.int16
    back = np.asarray(decode_tensor(p, "p16e1"))
    # golden zone: p16e1 carries >= 10 fraction bits for |x| in [1/16, 16)
    mask = (np.abs(x) > 1 / 16) & (np.abs(x) < 16)
    rel = np.abs(back[mask] - x[mask]) / np.abs(x[mask])
    assert rel.max() < 2 ** -10


def test_compressed_psum_multidevice_subprocess():
    """compressed_psum == psum (within p16 noise) on an 8-device mesh —
    run in a subprocess so the device-count flag doesn't leak."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.collectives import compressed_psum
        mesh = jax.make_mesh((8,), ("dp",))
        rng = np.random.default_rng(0)
        x = rng.standard_normal((8, 1024)).astype(np.float32) * 0.03
        def f(xs):
            a = compressed_psum(xs, "dp")
            b = jax.lax.psum(xs, "dp")
            return a, b
        g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("dp"),
                                  out_specs=P("dp"), axis_names={"dp"},
                                  check_vma=False))
        a, b = g(jnp.asarray(x))
        a, b = np.asarray(a), np.asarray(b)
        # elementwise relative error explodes on near-zero sums
        # (cancellation); bound the error against the RMS magnitude
        rel = np.abs(a - b) / (np.sqrt(np.mean(b ** 2)) + 1e-12)
        assert rel.max() < 5e-3, rel.max()
        print("OK", rel.max())
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-2000:]
