"""Householder QR + least-squares subsystem (lapack/qr.py).

Contracts, in the repo's two currencies:

* BIT-IDENTITY for schedule changes — blocked ``rgeqrf`` == Python-loop
  ``rgeqrf_loop`` per gemm backend, ``rgeqrf_batched`` == per-matrix
  ``rgeqrf``, and the exact-accumulation backend family (xla_quire,
  quire_exact: both round ONE exact sum per element) produces identical
  factor words.  ``faithful``/``pallas_split3`` legitimately differ
  (per-MAC rounding / f32 accumulation) and are covered by
  reconstruction tolerance instead.
* ACCURACY for the solvers — ``rgels_ir``/``rgels_mp`` must land on the
  true least-squares optimum of the posit-held problem (the
  over-determined floor is data quantization, not solver rounding:
  see ``LeastSquaresResult.digits_from_opt``), with the narrow
  factorization costing ~0 digits after refinement across the §5.1
  sigma grid.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import posit as P
from repro.core.formats import P16E1, P32E2
from repro.lapack import qr, refine
from repro.lapack.blas import rtrsm_left_upper
from repro.lapack.error_eval import least_squares_study
from repro.lapack.solve import rtrtrs
from repro.quire import quire_dot, quire_gemv


def _ls_problem(m, n, sigma=1.0, seed=0):
    rng = np.random.default_rng(seed)
    a64 = rng.standard_normal((m, n)) * sigma
    x_sol = np.full((n,), 1.0 / np.sqrt(n))
    b64 = a64 @ x_sol
    return a64, b64, x_sol


# --------------------------------------------------------------------------
# factorization: reconstruction + orthogonality
# --------------------------------------------------------------------------

@pytest.mark.parametrize("nb", [8, 16])
def test_rgeqrf_reconstruction(nb):
    a64, _, _ = _ls_problem(48, 32, seed=2)
    ap = P.from_float64(jnp.asarray(a64))
    qrp, tau = qr.rgeqrf(ap, nb=nb)
    rv = np.asarray(P.to_float64(qrp))[:32, :32]
    assert np.all(np.isfinite(rv))
    q = qr.rorgqr(qrp, tau, nb=nb)
    qv = np.asarray(P.to_float64(q))
    aq = np.asarray(P.to_float64(ap))
    rec = qv @ np.triu(rv)
    assert np.abs(qv.T @ qv - np.eye(32)).max() < 1e-6
    assert np.linalg.norm(rec - aq) / np.linalg.norm(aq) < 1e-6


def test_rgeqrf_wide_matrix():
    """m < n: factor the first m columns, update the trailing n - m."""
    a64, _, _ = _ls_problem(16, 24, seed=3)
    ap = P.from_float64(jnp.asarray(a64))
    qrp, tau = qr.rgeqrf(ap, nb=8)
    assert tau.shape == (16,)
    q = qr.rorgqr(qrp, tau, nb=8)
    qv = np.asarray(P.to_float64(q))
    rv = np.triu(np.asarray(P.to_float64(qrp)))
    aq = np.asarray(P.to_float64(ap))
    assert np.linalg.norm(qv @ rv - aq) / np.linalg.norm(aq) < 1e-6


# --------------------------------------------------------------------------
# bit-identity: schedule/dispatch changes round nothing differently
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla_quire", "quire_exact", "faithful",
                                     "pallas_split3"])
def test_rgeqrf_blocked_equals_loop(backend):
    a64, _, _ = _ls_problem(32, 24, seed=4)
    ap = P.from_float64(jnp.asarray(a64))
    qrj, tauj = qr.rgeqrf(ap, nb=12, gemm_backend=backend)
    qrl, taul = qr.rgeqrf_loop(ap, nb=12, gemm_backend=backend)
    assert np.array_equal(np.asarray(qrj), np.asarray(qrl)), backend
    assert np.array_equal(np.asarray(tauj), np.asarray(taul)), backend


def test_rgeqrf_batched_equals_single():
    rng = np.random.default_rng(5)
    a64 = rng.standard_normal((3, 40, 24))
    ap = P.from_float64(jnp.asarray(a64))
    qrb, taub = qr.rgeqrf_batched(ap, nb=8)
    for i in range(3):
        qs, ts = qr.rgeqrf(ap[i], nb=8)
        assert np.array_equal(np.asarray(qrb[i]), np.asarray(qs)), i
        assert np.array_equal(np.asarray(taub[i]), np.asarray(ts)), i


def test_rgeqrf_exact_backend_family_identical():
    """xla_quire and quire_exact both produce ONE rounding of an exact
    per-element sum, so the whole factorization's words must agree."""
    a64, _, _ = _ls_problem(40, 24, seed=6)
    ap = P.from_float64(jnp.asarray(a64))
    qx, tx = qr.rgeqrf(ap, nb=8, gemm_backend="xla_quire")
    qq, tq = qr.rgeqrf(ap, nb=8, gemm_backend="quire_exact")
    assert np.array_equal(np.asarray(qx), np.asarray(qq))
    assert np.array_equal(np.asarray(tx), np.asarray(tq))


def test_rgels_batched_equals_single():
    rng = np.random.default_rng(7)
    a64 = rng.standard_normal((2, 36, 20))
    b64 = np.einsum("bmn,n->bm", a64, np.full(20, 0.5))
    ap = P.from_float64(jnp.asarray(a64))
    bp = P.from_float64(jnp.asarray(b64))
    xb, (qrb, taub) = qr.rgels_batched(ap, bp, nb=8)
    for i in range(2):
        xs, (qs, ts) = qr.rgels(ap[i], bp[i], nb=8)
        assert np.array_equal(np.asarray(xb[i]), np.asarray(xs)), i
        assert np.array_equal(np.asarray(qrb[i]), np.asarray(qs)), i


# --------------------------------------------------------------------------
# applying Q: rormqr round-trip, quire_gemv identity
# --------------------------------------------------------------------------

def test_rormqr_roundtrip_and_matrix_rhs():
    a64, _, _ = _ls_problem(40, 24, seed=8)
    rng = np.random.default_rng(9)
    c64 = rng.standard_normal((40, 3))
    ap = P.from_float64(jnp.asarray(a64))
    cp = P.from_float64(jnp.asarray(c64))
    qrp, tau = qr.rgeqrf(ap, nb=8)
    qc = qr.rormqr(qrp, tau, cp, trans=False, nb=8)
    back = qr.rormqr(qrp, tau, qc, trans=True, nb=8)
    err = np.abs(np.asarray(P.to_float64(back)) - np.asarray(
        P.to_float64(cp))).max()
    assert err < 1e-6
    # vector RHS takes the same path (shape convention)
    qv = qr.rormqr(qrp, tau, cp[:, 0], trans=True, nb=8)
    assert qv.shape == (40,)
    assert np.array_equal(np.asarray(qv),
                          np.asarray(qr.rormqr(qrp, tau, cp, trans=True,
                                               nb=8))[:, 0])


def test_quire_gemv_matches_quire_dot():
    """The LS residual/correction matvec is the same exact fused dot the
    rest of the stack uses — bit-identical, per format."""
    rng = np.random.default_rng(10)
    for fmt in (P32E2, P16E1):
        a = P.from_float64(jnp.asarray(rng.standard_normal((17, 33))), fmt)
        x = P.from_float64(jnp.asarray(rng.standard_normal(33)), fmt)
        c0 = P.from_float64(jnp.asarray(rng.standard_normal(17)), fmt)
        got = quire_gemv(a, x, c0, fmt=fmt, negate=True)
        want = quire_dot(a, x[None, :], fmt, init_p=c0, negate=True)
        assert np.array_equal(np.asarray(got), np.asarray(want)), fmt.name


# --------------------------------------------------------------------------
# triangular helpers
# --------------------------------------------------------------------------

def test_rtrsm_left_upper_and_rtrtrs():
    rng = np.random.default_rng(11)
    n, m = 24, 4
    u64 = np.triu(rng.standard_normal((n, n))) + 4 * np.eye(n)
    b64 = rng.standard_normal((n, m))
    up = P.from_float64(jnp.asarray(u64))
    bp = P.from_float64(jnp.asarray(b64))
    x = np.asarray(P.to_float64(rtrsm_left_upper(up, bp)))
    want = np.linalg.solve(u64, b64)
    assert np.abs(x - want).max() / np.abs(want).max() < 1e-6
    # rtrtrs drives the same sweeps (vector form, quire and chain)
    for quire in (False, True):
        xv = np.asarray(P.to_float64(rtrtrs(up, bp[:, 0], lower=False,
                                            quire=quire)))
        assert np.abs(xv - want[:, 0]).max() / np.abs(want).max() < 1e-6


# --------------------------------------------------------------------------
# least squares: plain, refined, mixed-precision
# --------------------------------------------------------------------------

def test_rgels_recovers_solution():
    a64, b64, x_sol = _ls_problem(48, 32, seed=12)
    ap = P.from_float64(jnp.asarray(a64))
    bp = P.from_float64(jnp.asarray(b64))
    x, (qrp, tau) = qr.rgels(ap, bp, nb=16)
    xv = np.asarray(P.to_float64(x))
    assert np.abs(xv - x_sol).max() < 1e-5
    # multi-RHS convention
    b2 = P.from_float64(jnp.asarray(np.stack([b64, 2 * b64], axis=1)))
    x2, _ = qr.rgels(ap, b2, nb=16)
    assert x2.shape == (32, 2)


def test_rgels_ir_attains_ls_optimum():
    """The over-determined floor is the data-quantization residual
    (``e_opt``); the refined pair must sit on it, several digits below
    the plain QR solve."""
    r = least_squares_study(48, 32, sigma=1.0, seed=13, nb=16)
    assert r.digits_from_opt < 0.1, r
    assert r.digits_gained > 0.3, r


@pytest.mark.parametrize("sigma", [1e-2, 1.0, 1e2])
def test_rgels_mp_matches_ir_digits(sigma):
    """The p16e1-factorized LS refinement reaches the full-width floor
    across the sigma grid (equilibration makes it sigma-invariant)."""
    r = least_squares_study(48, 32, sigma=sigma, seed=14, nb=16)
    assert r.digits_lost < 0.5, r
    assert r.digits_from_opt < 0.1, r


def test_rgels_mp_factor_format_and_multi_rhs():
    a64, b64, _ = _ls_problem(36, 20, seed=15)
    b2 = np.stack([b64, -b64], axis=1)
    ap = P.from_float64(jnp.asarray(a64))
    bp = P.from_float64(jnp.asarray(b2))
    (xh, xl), (qr16, tau16) = qr.rgels_mp(ap, bp, nb=8)
    assert xh.shape == (20, 2)
    # p16e1 words live in [-2^15, 2^15)
    assert np.abs(np.asarray(qr16)).max() < (1 << 15)
    x = np.asarray(refine.pair_to_float64(xh, xl))
    aq = np.asarray(P.to_float64(ap))
    bq = np.asarray(P.to_float64(bp))
    want = np.linalg.lstsq(aq, bq, rcond=None)[0]
    assert np.abs(x - want).max() / np.abs(want).max() < 1e-9


def test_rgeqrf_p16e1_reconstructs():
    a64, _, _ = _ls_problem(32, 20, seed=16)
    ap = P.from_float64(jnp.asarray(a64), P16E1)
    qrp, tau = qr.rgeqrf(ap, nb=8, fmt=P16E1)
    q = qr.rorgqr(qrp, tau, nb=8, fmt=P16E1)
    qv = np.asarray(P.to_float64(q, P16E1))
    rv = np.triu(np.asarray(P.to_float64(qrp, P16E1))[:20, :20])
    aq = np.asarray(P.to_float64(ap, P16E1))
    assert np.linalg.norm(qv @ rv - aq) / np.linalg.norm(aq) < 5e-3


def test_least_squares_backward_error_vs_binary32():
    """Golden-zone cell: posit QR beats binary32 least squares (the
    Fig. 7 protocol extended to the over-determined scenario)."""
    r = least_squares_study(48, 32, sigma=1.0, seed=17, nb=16)
    assert r.digits > 0.2, r
