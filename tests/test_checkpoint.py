"""repro.checkpoint.store — exact round-trip of posit/quire state.

The FT contract (DESIGN.md §11) leans on checkpoints being *bit-exact*:
posit words are int32 and quire limb planes int64, so a resumed
factorization replays word-for-word only if save/restore is an identity
on both dtypes.  Round-trips, dtype/shape/integrity rejection, the
step_ GC window, and crash-atomicity of the tmp-dir publish.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                    save_checkpoint)


def _tree(rng):
    return {
        "words": rng.integers(-2**31, 2**31, (48, 48)).astype(np.int32),
        "limbs": rng.integers(-2**62, 2**62, (8, 16)).astype(np.int64),
        "ipiv": rng.integers(0, 48, (48,)).astype(np.int32),
    }


def test_roundtrip_bit_exact_int32_words_int64_limbs(tmp_path):
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 3, tree)
    got, step, extra = restore_checkpoint(str(tmp_path), tree)
    assert step == 3 and extra == {}
    for k in tree:
        assert got[k].dtype == tree[k].dtype, k
        assert np.array_equal(got[k], tree[k]), k


def test_roundtrip_jax_arrays_and_extra(tmp_path):
    import jax.numpy as jnp
    words = jnp.asarray(np.arange(64, dtype=np.int32).reshape(8, 8))
    save_checkpoint(str(tmp_path), 1, {"a": words},
                    extra={"nb": 32, "fmt": "p32e2"})
    got, step, extra = restore_checkpoint(str(tmp_path), {"a": words})
    assert extra == {"nb": 32, "fmt": "p32e2"}
    assert got["a"].dtype == np.int32
    assert np.array_equal(got["a"], np.asarray(words))


def test_latest_step_and_gc_window(tmp_path):
    rng = np.random.default_rng(1)
    tree = _tree(rng)
    assert latest_step(str(tmp_path)) is None
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), s, tree, keep_last=2)
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_restore_rejects_dtype_mismatch(tmp_path):
    """The FT bug class this store exists to prevent: int64 limbs loaded
    where int32 words are expected (or vice versa) must raise, never
    silently cast — a cast would corrupt bit-exact resumed state."""
    words = np.arange(16, dtype=np.int32).reshape(4, 4)
    save_checkpoint(str(tmp_path), 1, {"a": words})
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(str(tmp_path), {"a": words.astype(np.int64)})


def test_restore_rejects_shape_mismatch_and_leaf_count(tmp_path):
    words = np.arange(16, dtype=np.int32).reshape(4, 4)
    save_checkpoint(str(tmp_path), 1, {"a": words})
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(str(tmp_path), {"a": words.reshape(2, 8)})
    with pytest.raises(AssertionError, match="leaves"):
        restore_checkpoint(str(tmp_path), {"a": words, "b": words})


def test_restore_detects_corruption(tmp_path):
    words = np.arange(16, dtype=np.int32).reshape(4, 4)
    final = save_checkpoint(str(tmp_path), 1, {"a": words})
    leaf = os.path.join(final, "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0, 0] ^= 1 << 7                       # single-bit on-disk flip
    np.save(leaf, arr)
    with pytest.raises(IOError, match="integrity"):
        restore_checkpoint(str(tmp_path), {"a": words})


def test_manifest_dtype_pins_file_contents(tmp_path):
    """Manifest says int32 but the .npy was swapped for an int64 file of
    the same shape: restore must refuse on the manifest/file mismatch."""
    words = np.arange(16, dtype=np.int32).reshape(4, 4)
    final = save_checkpoint(str(tmp_path), 1, {"a": words})
    leaf = os.path.join(final, "leaf_00000.npy")
    np.save(leaf, words.astype(np.int64))
    # re-stamp the hash so the dtype check (not integrity) is exercised
    import hashlib
    with open(leaf, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()[:16]
    mpath = os.path.join(final, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["leaves"][0]["sha256_16"] = digest
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(str(tmp_path), {"a": words})


def test_interrupted_save_leaves_latest_intact(tmp_path):
    """A stale .tmp dir (crash mid-save) is invisible to latest_step and
    restore — the atomic-publish contract."""
    words = np.arange(16, dtype=np.int32).reshape(4, 4)
    save_checkpoint(str(tmp_path), 1, {"a": words})
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    got, step, _ = restore_checkpoint(str(tmp_path), {"a": words})
    assert step == 1 and np.array_equal(got["a"], words)
