"""Shared test plumbing.

``multi_device`` (fixture): a subprocess runner for tests that need a
real multi-device mesh.  ``--xla_force_host_platform_device_count`` only
takes effect before the jax backend initializes, so the in-process test
session (which already booted a 1-device CPU backend) can never see 8
devices — the fixture spawns a fresh interpreter with the flag set (the
``test_substrate.py`` pattern), asserts success, and returns stdout.  It
probes once per session and cleanly ``pytest.skip``s when the host
platform can't provide the devices (e.g. an exotic jaxlib build).

Tests using it should also carry ``@pytest.mark.multi_device`` (marker
registered in pyproject.toml) so the set is selectable:
``pytest -m "not multi_device"`` for a single-device-only box.
"""
import os
import subprocess
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.dirname(__file__))

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
_DEVICES = 8


def _spawn(code: str, timeout: float):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_DEVICES} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.fixture(scope="session")
def multi_device():
    """Returns ``run(code, timeout=600) -> stdout`` executing ``code`` in
    a fresh interpreter with 8 forced host devices; skips the requesting
    test when the platform can't provide them."""
    try:
        probe = _spawn(
            f"import jax; assert len(jax.devices()) >= {_DEVICES}, "
            f"len(jax.devices()); print('PROBE_OK')", timeout=240)
    except subprocess.TimeoutExpired:
        pytest.skip(f"{_DEVICES}-device probe timed out (overloaded box)")
    if probe.returncode != 0 or "PROBE_OK" not in probe.stdout:
        pytest.skip(f"{_DEVICES} host devices unavailable: "
                    f"{(probe.stderr or probe.stdout)[-500:]}")

    def run(code: str, timeout: float = 600) -> str:
        r = _spawn(code, timeout)
        assert r.returncode == 0, (
            f"multi-device subprocess failed\n--- stdout ---\n"
            f"{r.stdout[-2000:]}\n--- stderr ---\n{r.stderr[-4000:]}")
        return r.stdout

    return run
