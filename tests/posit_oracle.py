"""Independent pure-Python posit oracle (exact rational arithmetic).

Implemented from the posit definition (paper Eq. 1) with Python ints and
fractions — deliberately sharing NO code or structure with
repro.core.posit, so the property tests pin the JAX implementation against
a from-first-principles reference.

Rounding: posits are monotone in their (2's-complement) bit patterns, so
round-to-nearest is found by bracketing the real value between adjacent
patterns; ties pick the even pattern (posit standard / SoftPosit).
"""
from __future__ import annotations

from fractions import Fraction


def decode(pattern: int, nbits: int, es: int):
    """int pattern (low nbits significant, 2's complement) -> Fraction,
    or None for NaR."""
    mask = (1 << nbits) - 1
    p = pattern & mask
    if p == 0:
        return Fraction(0)
    if p == 1 << (nbits - 1):
        return None                                    # NaR
    neg = bool(p >> (nbits - 1))
    if neg:
        p = (-p) & mask
    # regime: run of identical bits after the sign bit
    bits = [(p >> i) & 1 for i in range(nbits - 2, -1, -1)]
    r0 = bits[0]
    m = 1
    while m < len(bits) and bits[m] == r0:
        m += 1
    k = (m - 1) if r0 == 1 else -m
    rest = bits[m + 1:] if m < len(bits) else []       # skip terminator
    e_bits = rest[:es]
    e = 0
    for b in e_bits:
        e = 2 * e + b
    e <<= (es - len(e_bits))                           # truncated e -> 0s
    f_bits = rest[es:]
    frac = Fraction(0)
    for i, b in enumerate(f_bits):
        frac += Fraction(b, 2 ** (i + 1))
    useed = Fraction(2) ** (1 << es)
    val = (useed ** k) * (Fraction(2) ** e) * (1 + frac)
    return -val if neg else val


def all_values(nbits: int, es: int):
    """[(pattern, value)] for all non-NaR patterns, ascending by value."""
    half = 1 << (nbits - 1)
    out = []
    for p in range(-half + 1, half):
        out.append((p, decode(p, nbits, es)))
    out.sort(key=lambda t: t[1])
    return out


def encode(x, nbits: int, es: int) -> int:
    """Round Fraction/None to the nearest posit pattern (sign-extended int).

    Saturates at +-maxpos; nonzero magnitudes below minpos round to minpos
    (posit standard).  Ties pick the even pattern.
    """
    if x is None:
        return -(1 << (nbits - 1))
    x = Fraction(x)
    if x == 0:
        return 0
    neg = x < 0
    ax = -x if neg else x
    maxpos_pat = (1 << (nbits - 1)) - 1
    maxpos = decode(maxpos_pat, nbits, es)
    minpos = decode(1, nbits, es)
    if ax >= maxpos:
        pat = maxpos_pat
    elif ax <= minpos:
        pat = 1
    else:
        # binary search on positive patterns (monotone in value)
        lo, hi = 1, maxpos_pat
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if decode(mid, nbits, es) <= ax:
                lo = mid
            else:
                hi = mid
        # The posit standard rounds the ENCODING bit-string (RNE on the
        # field), not the value.  The field midpoint between adjacent
        # nbits-posits lo and hi is exactly the (nbits+1)-bit posit
        # (lo<<1)|1 — append one more encoding bit set to 1.
        vmid = decode((lo << 1) | 1, nbits + 1, es)
        if ax < vmid:
            pat = lo
        elif ax > vmid:
            pat = hi
        else:
            pat = lo if lo % 2 == 0 else hi            # tie -> even pattern
    return -pat if neg else pat


def sqrt_nearest(x: Fraction, nbits: int, es: int) -> int:
    """Nearest posit to sqrt(x) for x >= 0, via exact squared comparisons."""
    if x == 0:
        return 0
    maxpos_pat = (1 << (nbits - 1)) - 1
    lo, hi = 1, maxpos_pat
    # find bracket: largest pattern with val^2 <= x
    if decode(1, nbits, es) ** 2 > x:
        lo = hi = 1
    elif decode(maxpos_pat, nbits, es) ** 2 <= x:
        lo = hi = maxpos_pat
    else:
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if decode(mid, nbits, es) ** 2 <= x:
                lo = mid
            else:
                hi = mid
    if lo == hi:
        return lo
    # pattern-space rounding (see encode): compare x with vmid^2 exactly
    vmid = decode((lo << 1) | 1, nbits + 1, es)
    if x < vmid * vmid:
        return lo
    if x > vmid * vmid:
        return hi
    return lo if lo % 2 == 0 else hi
